//! `artifacts/manifest.json` loader — the contract between the Python AOT
//! path and the Rust runtime: model shape, artifact parameter order, and
//! the weight-tensor inventory.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Model configuration (mirrors `python/compile/config.py`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub prefill_len: usize,
    pub max_seq: usize,
    pub seed: u64,
}

/// One parameter of an artifact, in PJRT parameter order.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT-lowered entry point.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub params: Vec<ParamSpec>,
}

/// One exported weight tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub file: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> u64 {
        4 * self.elements() as u64 // f32 export
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelConfig,
    pub layer_weight_names: Vec<String>,
    pub attn_weight_names: Vec<String>,
    pub mlp_weight_names: Vec<String>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub tensors: BTreeMap<String, TensorSpec>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&src).context("parsing manifest.json")?;

        let usize_field = |obj: &Json, key: &str| -> Result<usize> {
            obj.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest model.{key} missing or not an integer"))
        };
        let m = root
            .get("model")
            .ok_or_else(|| anyhow!("manifest missing 'model'"))?;
        let model = ModelConfig {
            name: m
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("TinyLM")
                .to_string(),
            vocab: usize_field(m, "vocab")?,
            hidden: usize_field(m, "hidden")?,
            layers: usize_field(m, "layers")?,
            heads: usize_field(m, "heads")?,
            kv_heads: usize_field(m, "kv_heads")?,
            head_dim: usize_field(m, "head_dim")?,
            ffn: usize_field(m, "ffn")?,
            prefill_len: usize_field(m, "prefill_len")?,
            max_seq: usize_field(m, "max_seq")?,
            seed: m.get("seed").and_then(Json::as_u64).unwrap_or(0),
        };

        let str_list = |key: &str| -> Result<Vec<String>> {
            root.get(key)
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|v| v.as_str().map(str::to_string))
                        .collect()
                })
                .ok_or_else(|| anyhow!("manifest missing '{key}'"))
        };

        let mut artifacts = BTreeMap::new();
        for (name, art) in root
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?
        {
            let file = art
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                .to_string();
            let mut params = Vec::new();
            for p in art
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {name} missing params"))?
            {
                params.push(ParamSpec {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("param missing name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .map(|a| a.iter().filter_map(Json::as_usize).collect())
                        .unwrap_or_default(),
                    dtype: p
                        .get("dtype")
                        .and_then(Json::as_str)
                        .unwrap_or("float32")
                        .to_string(),
                });
            }
            artifacts.insert(name.clone(), ArtifactSpec { file, params });
        }

        let mut tensors = BTreeMap::new();
        for (name, t) in root
            .get("tensors")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'tensors'"))?
        {
            tensors.insert(
                name.clone(),
                TensorSpec {
                    shape: t
                        .get("shape")
                        .and_then(Json::as_arr)
                        .map(|a| a.iter().filter_map(Json::as_usize).collect())
                        .unwrap_or_default(),
                    file: t
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("tensor {name} missing file"))?
                        .to_string(),
                },
            );
        }

        let manifest = Manifest {
            dir,
            model,
            layer_weight_names: str_list("layer_weight_names")?,
            attn_weight_names: str_list("attn_weight_names")?,
            mlp_weight_names: str_list("mlp_weight_names")?,
            artifacts,
            tensors,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    fn validate(&self) -> Result<()> {
        for required in [
            "embed_prefill",
            "embed_decode",
            "layer_prefill",
            "layer_decode",
            "mha_decode",
            "mlp_decode",
            "lm_head",
        ] {
            if !self.artifacts.contains_key(required) {
                return Err(anyhow!("manifest missing artifact '{required}'"));
            }
        }
        for li in 0..self.model.layers {
            for w in &self.layer_weight_names {
                let key = format!("layer{li}.{w}");
                if !self.tensors.contains_key(&key) {
                    return Err(anyhow!("manifest missing tensor '{key}'"));
                }
            }
        }
        Ok(())
    }

    /// Absolute path of an artifact's HLO text.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let a = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        Ok(self.dir.join(&a.file))
    }

    /// Absolute path of a tensor blob.
    pub fn tensor_path(&self, name: &str) -> Result<PathBuf> {
        let t = self
            .tensors
            .get(name)
            .ok_or_else(|| anyhow!("unknown tensor '{name}'"))?;
        Ok(self.dir.join(&t.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_built_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert_eq!(m.model.layers, 8);
        assert_eq!(m.model.hidden, 128);
        assert_eq!(m.artifacts.len(), 7);
        assert_eq!(m.layer_weight_names.len(), 9);
        // Parameter order sanity for layer_decode.
        let ld = &m.artifacts["layer_decode"];
        assert_eq!(ld.params[0].name, "x");
        assert_eq!(ld.params[3].name, "pos");
        assert_eq!(ld.params[3].dtype, "int32");
    }

    #[test]
    fn tensor_paths_exist() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        for name in ["embed", "ln_f", "layer0.wq", "layer7.w_down"] {
            let p = m.tensor_path(name).unwrap();
            assert!(p.exists(), "{p:?}");
            let spec = &m.tensors[name];
            assert_eq!(
                std::fs::metadata(&p).unwrap().len(),
                spec.bytes(),
                "{name} blob size"
            );
        }
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load("/nonexistent/artifacts").is_err());
    }
}
