//! The PJRT runtime: manifest loading, executable cache, and the
//! residency-aware weight store. Python never runs here — artifacts are
//! produced once by `make artifacts`.
//!
//! The executable cache and weight store sit on the `xla` PJRT bindings,
//! which need the native `xla_extension` library. They are gated behind the
//! off-by-default `pjrt` cargo feature so the simulator/scheduler stack
//! builds and tests everywhere (see Cargo.toml for how to enable it);
//! manifest parsing is pure Rust and always available.

pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub mod weights;

pub use manifest::{Manifest, ModelConfig};
#[cfg(feature = "pjrt")]
pub use pjrt::{
    argmax_logits, literal_from_f32, literal_from_f32_file, literal_from_i32,
    literal_scalar_i32, PjrtRuntime,
};
#[cfg(feature = "pjrt")]
pub use weights::{Residency, WeightStore};
