//! The PJRT runtime: manifest loading, executable cache, and the
//! residency-aware weight store. Python never runs here — artifacts are
//! produced once by `make artifacts`.

pub mod manifest;
pub mod pjrt;
pub mod weights;

pub use manifest::{Manifest, ModelConfig};
pub use pjrt::{
    argmax_logits, literal_from_f32, literal_from_f32_file, literal_from_i32,
    literal_scalar_i32, PjrtRuntime,
};
pub use weights::{Residency, WeightStore};
