//! `lime` CLI — plan allocations, run simulated experiments, and serve the
//! real TinyLM through PJRT.
//!
//! Subcommands:
//!   plan       run the offline scheduler for a model/cluster and print it
//!   simulate   simulate LIME (or a baseline) and report ms/token
//!   serve      end-to-end TinyLM serving through the PJRT runtime
//!   experiments run a named paper experiment (fig12/fig13/.../tab5)

use lime::util::cli::Cli;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{}", usage());
        std::process::exit(2);
    }
    let sub = argv.remove(0);
    match sub.as_str() {
        "plan" => cmd_plan(&argv),
        "simulate" => cmd_simulate(&argv),
        "serve" => cmd_serve(&argv),
        "experiments" => cmd_experiments(&argv),
        "fleet" => cmd_fleet(&argv),
        "bench-check" => cmd_bench_check(&argv),
        "sweep-check" => cmd_sweep_check(&argv),
        "--help" | "-h" | "help" => println!("{}", usage()),
        other => {
            eprintln!("unknown subcommand '{other}'\n{}", usage());
            std::process::exit(2);
        }
    }
}

fn usage() -> String {
    "lime — collaborative lossless LLM inference on memory-constrained edge devices\n\
     \n\
     Usage: lime <subcommand> [options]\n\
     \n\
     Subcommands:\n\
       plan         offline allocation for a model on a cluster\n\
       simulate     simulated inference latency (LIME or a baseline)\n\
       serve        real TinyLM serving via the PJRT runtime\n\
       experiments  regenerate a paper figure/table (fig2a fig2b fig12 fig13\n\
                    fig14 lowmem fig18 tab5), or `sweep` for the scenario\n\
                    matrix (lowmem + cluster-size grids × bandwidth ×\n\
                    pattern, #Seg-override, joint memory/bandwidth\n\
                    pressure-script, arrival-process, device-churn,\n\
                    batching-policy and workload-mix axes — continuous\n\
                    request streams with per-request TTFT/queueing-delay\n\
                    and length metrics, FIFO vs step-level continuous\n\
                    batching with paged-KV counters, fixed vs bimodal\n\
                    request lengths, plus re-plan/KV-migration/recovery\n\
                    counters) with one lime-sweep-v7 JSON per grid\n\
       fleet        fleet-sharded request streams: N heterogeneous clusters\n\
                    behind a global event-driven admission router (rr/jsq/\n\
                    plan), tail-latency quantiles streamed as one\n\
                    lime-fleet-v1 JSON, with optional cluster churn\n\
                    (down/up + re-routing); `--affinity` adds sticky-\n\
                    session KV-reuse routing and upgrades the artifact\n\
                    to lime-fleet-v2\n\
       sweep-check  validate sweep/fleet JSON artifacts against the\n\
                    lime-sweep-v2..v7 and lime-fleet-v1/v2 schemas\n\
                    (non-zero exit on violation)\n\
       bench-check  diff a fresh BENCH_*.json against a committed baseline\n\
                    with a tolerance band (non-zero exit on regression);\n\
                    `--max-unpinned N` also fails when more than N\n\
                    baseline entries are unpinned (mean_s 0)\n\
     \n\
     Run `lime <subcommand> --help` for options."
        .to_string()
}

fn cmd_plan(argv: &[String]) {
    let cli = Cli::new("lime plan", "offline allocation scheduler (Alg. 1)")
        .opt("model", "llama3.3-70b", "model preset (llama2-13b|qwen3-32b|llama3.3-70b|tiny)")
        .opt("env", "e3", "cluster preset (e1|e2|e3|lowmem1|lowmem2|lowmem3)")
        .opt("config", "", "TOML deployment file (overrides --model/--env)")
        .opt("bandwidth-mbps", "200", "network bandwidth in Mbps")
        .opt("micro-batch", "1", "micro-batch size (1=sporadic, |D|=bursty)")
        .opt("tokens", "512", "empirical output length n");
    let args = parse(&cli, argv);
    let (spec, cluster, bw_cfg) = resolve_deployment(&args);
    let opts = lime::plan::PlanOptions {
        empirical_tokens: args.get_usize("tokens"),
        micro_batch: args.get_usize("micro-batch"),
        bandwidth: bw_cfg
            .unwrap_or_else(|| lime::util::bytes::mbps(args.get_f64("bandwidth-mbps"))),
    };
    match lime::plan::plan(&spec, &cluster, &opts) {
        Ok(report) => {
            print!("{}", report.allocation.describe());
            println!(
                "predicted per-token: comp {:.1} ms, comm {:.1} ms, uncovered load {:.1} ms, total {:.1} ms",
                report.cost.t_comp * 1e3,
                report.cost.t_comm * 1e3,
                report.cost.t_uncover * 1e3,
                report.cost.total() * 1e3
            );
        }
        Err(e) => {
            eprintln!("planning failed: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_simulate(argv: &[String]) {
    let cli = Cli::new("lime simulate", "simulated collaborative inference")
        .opt("model", "llama3.3-70b", "model preset")
        .opt("env", "e3", "cluster preset")
        .opt("config", "", "TOML deployment file (overrides --model/--env)")
        .opt("method", "lime", "lime|pp|pp-offload|edgeshard|galaxy|tpi-llm|tpi-llm-offload")
        .opt("bandwidth-mbps", "200", "network bandwidth in Mbps")
        .opt("pattern", "sporadic", "request pattern: sporadic|bursty")
        .opt("tokens", "256", "tokens to generate")
        .flag("trace", "print the pipeline Gantt chart");
    let args = parse(&cli, argv);
    let (spec, cluster, bw_cfg) = resolve_deployment(&args);
    let bw = lime::net::BandwidthTrace::Fixed(
        bw_cfg.unwrap_or_else(|| lime::util::bytes::mbps(args.get_f64("bandwidth-mbps"))),
    );
    let pattern = match args.get("pattern") {
        "bursty" => lime::workload::Pattern::Bursty,
        _ => lime::workload::Pattern::Sporadic,
    };
    let method = lime::baselines::by_name(args.get("method")).unwrap_or_else(|| {
        eprintln!("unknown method {}", args.get("method"));
        std::process::exit(2);
    });
    let outcome = method.run(&spec, &cluster, &bw, pattern, args.get_usize("tokens"));
    match outcome {
        lime::baselines::Outcome::Ok(res) => {
            println!(
                "{} {} on {}: {:.1} ms/token ({} tokens, pattern {:?})",
                method.name(),
                spec.name,
                args.get("env"),
                res.ms_per_token(),
                res.tokens,
                pattern
            );
            if args.get_flag("trace") {
                println!("{}", res.trace.render(cluster.len(), 110));
            }
        }
        lime::baselines::Outcome::Oom(msg) => println!("{}: OOM ({msg})", method.name()),
    }
}

fn cmd_serve(argv: &[String]) {
    let cli = Cli::new("lime serve", "real TinyLM serving through PJRT")
        .opt("artifacts", "artifacts", "artifact directory from `make artifacts`")
        .opt("requests", "8", "number of requests")
        .opt("steps", "32", "decode steps per request")
        .opt("pattern", "sporadic", "sporadic|bursty")
        .opt("devices", "4", "simulated device count")
        .flag("verify", "check losslessness vs monolithic execution");
    let args = parse(&cli, argv);
    if let Err(e) = lime::serve::run_server_demo(
        args.get("artifacts"),
        args.get_usize("requests"),
        args.get_usize("steps"),
        args.get("pattern") == "bursty",
        args.get_usize("devices"),
        args.get_flag("verify"),
    ) {
        eprintln!("serve failed: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_experiments(argv: &[String]) {
    let cli = Cli::new("lime experiments", "regenerate a paper figure/table")
        .opt("id", "fig14", "fig2a|fig2b|fig7|fig12|fig13|fig14|lowmem|fig18|tab5|sweep")
        .opt("tokens", "128", "tokens per run")
        .opt("out", "sweeps", "output directory for `--id sweep` JSON grids");
    let args = parse(&cli, argv);
    lime::experiments::run_by_id(args.get("id"), args.get_usize("tokens"), args.get("out"));
}

fn cmd_fleet(argv: &[String]) {
    let cli = Cli::new(
        "lime fleet",
        "fleet-sharded request streams over heterogeneous clusters",
    )
    .opt("count", "2000", "requests per (router, pattern) cell")
    .opt("tokens", "4", "decode steps per request")
    .opt("out", "sweeps", "output directory for the FLEET_*.json artifact")
    .flag(
        "affinity",
        "enable sticky-session KV-reuse routing (emits a lime-fleet-v2 artifact)",
    );
    let args = parse(&cli, argv);
    let count = args.get_usize("count");
    let tokens = args.get_usize("tokens");
    // validate_fleet rejects zero counts/steps — refuse to write an
    // artifact our own sweep-check would then fail the directory on.
    if count == 0 || tokens == 0 {
        eprintln!("fleet: --count and --tokens must be positive");
        std::process::exit(2);
    }
    // The affinity demo spec carries a distinct name, so the v2 artifact
    // lands next to (never over) the plain v1 one in the same directory.
    let spec = if args.get_flag("affinity") {
        lime::serve::FleetSpec::demo_affinity(count, tokens)
    } else {
        lime::serve::FleetSpec::demo(count, tokens)
    };
    let cells = lime::serve::run_fleet(&spec);
    let dir = args.get("out");
    if let Err(e) = std::fs::create_dir_all(dir) {
        eprintln!("fleet: cannot create {dir}: {e}");
        std::process::exit(2);
    }
    let path = format!("{dir}/FLEET_{}.json", spec.name);
    let file = std::fs::File::create(&path).unwrap_or_else(|e| {
        eprintln!("fleet: cannot create {path}: {e}");
        std::process::exit(2);
    });
    // Streamed cell-by-cell: the artifact never exists as one in-memory
    // tree, however many requests the cells served.
    let result = lime::serve::write_fleet(&spec, &cells, std::io::BufWriter::new(file))
        .and_then(|mut out| std::io::Write::write_all(&mut out, b"\n"));
    if let Err(e) = result {
        eprintln!("fleet: cannot write {path}: {e}");
        std::process::exit(2);
    }
    println!(
        "fleet: {} ({}, {}) — {} clusters, {} cells x {} requests -> {path}",
        spec.name,
        spec.model(),
        lime::serve::fleet::schema_tag(&spec),
        spec.clusters.len(),
        cells.len(),
        spec.count
    );
    println!(
        "{:6} {:9} {:>12} {:>12} {:>14} {:>12}",
        "router", "pattern", "ttft p50 s", "ttft p99 s", "queue p99 s", "makespan s"
    );
    for c in &cells {
        println!(
            "{:6} {:9} {:>12.3} {:>12.3} {:>14.3} {:>12.1}",
            c.router.key(),
            lime::serve::fleet::pattern_key(c.pattern),
            c.ttft.p50,
            c.ttft.p99,
            c.queueing.p99,
            c.makespan
        );
    }
}

fn cmd_sweep_check(argv: &[String]) {
    let cli = Cli::new(
        "lime sweep-check",
        "validate sweep/fleet artifacts against the lime-sweep-v2..v7 and lime-fleet-v1/v2 schemas",
    )
    .opt("dir", "sweeps", "directory holding SWEEP_*.json / FLEET_*.json artifacts")
    .opt("file", "", "validate a single artifact instead of a directory");
    let args = parse(&cli, argv);
    let files: Vec<std::path::PathBuf> = if !args.get("file").is_empty() {
        vec![std::path::PathBuf::from(args.get("file"))]
    } else {
        // The collection + zero-artifact guard lives in the library
        // (`experiments::collect_sweep_artifacts`) so its "a sweep that
        // wrote nothing must fail the check" contract is unit-tested.
        match lime::experiments::collect_sweep_artifacts(args.get("dir")) {
            Ok(files) => files,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    };
    let mut failures = 0usize;
    for path in &files {
        let parsed = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read: {e}"))
            .and_then(|src| {
                lime::util::json::Json::parse(src.trim()).map_err(|e| format!("invalid JSON: {e}"))
            });
        // Dispatch on the artifact's own schema tag, not the file name, so
        // `--file` works on either family.
        let verdict = parsed.and_then(|json| {
            if matches!(
                json.get("schema").and_then(lime::util::json::Json::as_str),
                Some("lime-fleet-v1" | "lime-fleet-v2")
            ) {
                lime::serve::validate_fleet(&json).map(|s| {
                    format!(
                        "fleet {} ({}, {}), {} clusters, {} cells x {} requests",
                        s.name, s.model, s.schema, s.clusters, s.cells, s.requests
                    )
                })
            } else {
                lime::experiments::validate_sweep(&json).map(|s| {
                    format!(
                        "grid {} ({}, {}), {} cells: {} completed, {} OOM, {} OOT",
                        s.grid, s.model, s.schema, s.cells, s.completed, s.oom, s.oot
                    )
                })
            }
        });
        match verdict {
            Ok(line) => println!("sweep-check: OK {} — {line}", path.display()),
            Err(e) => {
                eprintln!("sweep-check: FAIL {}: {e}", path.display());
                failures += 1;
            }
        }
    }
    if failures > 0 {
        eprintln!("sweep-check: {failures}/{} artifacts failed validation", files.len());
        std::process::exit(1);
    }
    println!("sweep-check: all {} artifacts valid", files.len());
}

fn cmd_bench_check(argv: &[String]) {
    let cli = Cli::new(
        "lime bench-check",
        "fail when a bench run regresses past the committed baseline",
    )
    .opt("current", "BENCH_scheduler_perf.json", "fresh bench snapshot")
    .opt(
        "baseline",
        "ci/BENCH_scheduler_perf.baseline.json",
        "committed lime-bench-v1 baseline",
    )
    .opt("tolerance", "2.0", "fail when current mean > tolerance x baseline mean")
    .opt(
        "max-unpinned",
        "",
        "fail when more than N baseline entries are unpinned (mean_s 0; empty = unlimited)",
    )
    .opt(
        "emit-candidate",
        "",
        "also write the current snapshot as a ready-to-commit candidate baseline",
    );
    let args = parse(&cli, argv);
    let load = |path: &str| -> lime::util::json::Json {
        let src = std::fs::read_to_string(path).unwrap_or_else(|e| {
            eprintln!("bench-check: cannot read {path}: {e}");
            std::process::exit(2);
        });
        lime::util::json::Json::parse(src.trim()).unwrap_or_else(|e| {
            eprintln!("bench-check: {path} is not valid JSON: {e}");
            std::process::exit(2);
        })
    };
    let current = load(args.get("current"));
    let baseline = load(args.get("baseline"));
    let tolerance = args.get_f64("tolerance");
    // Candidate-baseline flow: CI emits this artifact on every main-branch
    // run, so pinning the committed baseline is "download artifact, commit
    // it" instead of requiring a local reference machine. Written before
    // the gate below — the run a regression rejects is exactly the run a
    // maintainer may want to promote after investigating.
    let candidate_path = args.get("emit-candidate");
    if !candidate_path.is_empty() {
        let mut candidate = current.clone();
        if let lime::util::json::Json::Obj(map) = &mut candidate {
            map.insert(
                "note".to_string(),
                lime::util::json::Json::Str(
                    "Candidate baseline generated by `lime bench-check --emit-candidate` \
                     from a CI bench run. To pin: review the means, copy this file to \
                     rust/ci/BENCH_scheduler_perf.baseline.json, and commit."
                        .to_string(),
                ),
            );
        }
        if let Err(e) = std::fs::write(candidate_path, format!("{candidate}\n")) {
            eprintln!("bench-check: cannot write candidate baseline {candidate_path}: {e}");
            std::process::exit(2);
        }
        println!("bench-check: wrote candidate baseline {candidate_path}");
    }
    match lime::util::bench::check_regression(&current, &baseline, tolerance) {
        Ok(report) => {
            println!(
                "bench-check: {} vs {} (tolerance {tolerance:.2}x)",
                args.get("current"),
                args.get("baseline")
            );
            for line in &report.lines {
                println!("{line}");
            }
            // An all-unpinned baseline (every mean_s: 0) gates nothing —
            // say so explicitly instead of printing a green "OK" that
            // looks like a pass.
            if report.unpinned > 0 {
                println!(
                    "bench-check: {} baseline entr{} unpinned (mean_s 0 or non-finite) — \
                     not gated; record a baseline to pin (see README.md, Benchmarks)",
                    report.unpinned,
                    if report.unpinned == 1 { "y" } else { "ies" }
                );
            }
            // --max-unpinned turns the warning above into a ratchet: once a
            // baseline is (mostly) pinned, CI can stop it from silently
            // drifting back to an all-zero, gate-nothing state.
            let max_unpinned = args.get("max-unpinned");
            if !max_unpinned.is_empty() {
                let cap: usize = max_unpinned.parse().unwrap_or_else(|_| {
                    eprintln!("bench-check: --max-unpinned expects an integer, got '{max_unpinned}'");
                    std::process::exit(2);
                });
                if report.unpinned > cap {
                    eprintln!(
                        "bench-check: {} unpinned baseline entries exceed --max-unpinned {cap}",
                        report.unpinned
                    );
                    std::process::exit(1);
                }
            }
            if report.failures.is_empty() {
                println!("bench-check: OK");
            } else {
                for failure in &report.failures {
                    eprintln!("bench-check: {failure}");
                }
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("bench-check: {e}");
            std::process::exit(2);
        }
    }
}

fn parse(cli: &Cli, argv: &[String]) -> lime::util::cli::Args {
    match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

/// Resolve (model, cluster, config-bandwidth) from --config or presets.
fn resolve_deployment(
    args: &lime::util::cli::Args,
) -> (lime::model::ModelSpec, lime::cluster::Cluster, Option<f64>) {
    let cfg_path = args.get("config");
    if !cfg_path.is_empty() {
        match lime::cluster::Deployment::load(cfg_path) {
            Ok(d) => return (d.model, d.cluster, Some(d.bandwidth)),
            Err(e) => {
                eprintln!("failed to load config {cfg_path}: {e:#}");
                std::process::exit(2);
            }
        }
    }
    let (spec, cluster) = presets(args.get("model"), args.get("env"));
    (spec, cluster, None)
}

fn presets(model: &str, env: &str) -> (lime::model::ModelSpec, lime::cluster::Cluster) {
    let spec = lime::model::ModelSpec::by_name(model).unwrap_or_else(|| {
        eprintln!("unknown model preset '{model}'");
        std::process::exit(2);
    });
    let cluster = match env {
        "e1" => lime::cluster::Cluster::env_e1(),
        "e2" => lime::cluster::Cluster::env_e2(),
        "e3" => lime::cluster::Cluster::env_e3(),
        "lowmem1" => lime::cluster::Cluster::lowmem_setting1(),
        "lowmem2" => lime::cluster::Cluster::lowmem_setting2(),
        "lowmem3" => lime::cluster::Cluster::lowmem_setting3(),
        other => {
            eprintln!("unknown env preset '{other}'");
            std::process::exit(2);
        }
    };
    (spec, cluster)
}
