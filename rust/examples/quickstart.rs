//! Quickstart: plan Llama3.3-70B over four heterogeneous Jetsons, predict
//! per-token latency with the Eq. 1 cost model, simulate LIME vs the naive
//! pipeline, and print the interleaved schedule.
//!
//! Run with: `cargo run --release --example quickstart`

use lime::cluster::Cluster;
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::pipeline::{run_interleaved, run_traditional, ExecOptions, TradOptions};
use lime::plan::{plan, PlanOptions};
use lime::util::bytes::mbps;

fn main() {
    // 1. Describe the deployment: the paper's low-memory Setting 1 —
    //    Llama3.3-70B across five Jetson boards that cannot hold it.
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    println!(
        "model: {} ({} layers, {:.1} GiB)",
        spec.name,
        spec.layers,
        spec.total_bytes() as f64 / (1u64 << 30) as f64
    );
    for (i, d) in cluster.devices.iter().enumerate() {
        println!("  dev{i}: {:14} usable {}", d.name, lime::util::bytes::fmt_bytes(d.usable_mem()));
    }

    // 2. Offline scheduler (Alg. 1): layers, offload sets, #Seg.
    let opts = PlanOptions {
        empirical_tokens: 256,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };
    let report = plan(&spec, &cluster, &opts).expect("planning failed");
    print!("\n{}", report.allocation.describe());
    println!(
        "cost model: comp {:.0} ms + comm {:.0} ms + uncovered {:.0} ms = {:.0} ms/token",
        report.cost.t_comp * 1e3,
        report.cost.t_comm * 1e3,
        report.cost.t_uncover * 1e3,
        report.cost.total() * 1e3
    );

    // 3. Simulate 32 decode steps: LIME vs traditional pipeline+offload.
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let lime_run = run_interleaved(&report.allocation, &cluster, &bw, 1, 32, &ExecOptions::default());
    let trad_run = run_traditional(&report.allocation, &cluster, &bw, 1, 32, &TradOptions::default());
    println!(
        "\nsimulated 32 tokens @200 Mbps (sporadic):\n  LIME interleaved:        {:8.1} ms/token\n  traditional PP+offload:  {:8.1} ms/token\n  speedup:                 {:8.2}x",
        lime_run.ms_per_token(),
        trad_run.ms_per_token(),
        trad_run.ms_per_token() / lime_run.ms_per_token()
    );

    // 4. Show the interleaved schedule (compare with paper Figs 3b/6).
    println!("\ninterleaved schedule (first steps):");
    println!("{}", lime_run.trace.render(cluster.len(), 110));
}
