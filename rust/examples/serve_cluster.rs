//! End-to-end driver (DESIGN.md deliverable (b)/e2e): load the real TinyLM
//! artifacts, deploy them across a memory-constrained virtual edge cluster
//! with the offline scheduler, serve batched requests through the PJRT
//! runtime with *real* SSD weight streaming, report latency/throughput, and
//! verify losslessness against the fully resident engine.
//!
//! Requires `make artifacts` first. Run:
//! `cargo run --release --example serve_cluster`

use lime::runtime::Manifest;
use lime::serve::{
    make_requests, plan_tiny, residency_plan, serve, virtual_cluster, Engine, LayerResidency,
};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let manifest = Manifest::load(&artifacts)?;
    let cfg = manifest.model.clone();
    let mut engine = Engine::new(manifest)?;
    println!(
        "loaded {} ({} layers, hidden {}, vocab {}) on PJRT [{}], artifacts: {:?}",
        cfg.name,
        cfg.layers,
        cfg.hidden,
        cfg.vocab,
        engine.runtime.platform(),
        engine.runtime.artifact_names(),
    );

    // Deploy over 4 virtual devices that each hold ~1 layer resident: the
    // offline scheduler must offload the rest, exactly like the paper's
    // memory-constrained Jetsons.
    let cluster = virtual_cluster(4, &[1, 1, 1, 1]);
    let alloc = plan_tiny(&cluster, 48).map_err(|e| anyhow::anyhow!("{e}"))?;
    print!("\noffline plan over the virtual edge cluster:\n{}", alloc.describe());
    let plan = residency_plan(&alloc);
    engine.set_residency(&plan)?;

    // Serve a burst of 8 requests, 24 decode steps each.
    let reqs = make_requests(true, 8, 24, cfg.prefill_len, cfg.vocab, 42);
    let reqs_copy = reqs.clone();
    let report = serve(&mut engine, reqs, false)?;
    println!(
        "\nburst of {} requests x {} tokens:\n  prefill   {:8.2} ms mean\n  decode    {:8.2} ms/token p50, {:8.2} ms/token p99\n  throughput {:7.1} tokens/s\n  SSD weight re-reads: {}",
        report.requests,
        report.tokens / report.requests,
        report.prefill_mean * 1e3,
        report.token_p50 * 1e3,
        report.token_p99 * 1e3,
        report.throughput,
        engine.weights.loads_from_disk()
    );
    for (i, g) in report.generations.iter().take(3).enumerate() {
        println!("  request {i}: {:?}", g.tokens);
    }

    // Losslessness: the offloaded deployment must match the fully resident
    // engine token-for-token and bit-for-bit on logits.
    engine.set_residency(&vec![LayerResidency::Resident; cfg.layers])?;
    let resident = serve(&mut engine, reqs_copy, false)?;
    let identical = resident
        .generations
        .iter()
        .zip(&report.generations)
        .all(|(a, b)| a == b);
    if identical {
        println!("\nLOSSLESS: offloaded serving is bit-identical to resident serving ✓");
        Ok(())
    } else {
        anyhow::bail!("losslessness check FAILED");
    }
}
