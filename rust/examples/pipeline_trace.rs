//! Reproduce the paper's schedule figures (Figs 3, 4): print Gantt traces
//! of the traditional pipeline-with-offloading schedule next to LIME's
//! interleaved schedule, under both request patterns.
//!
//! Run with: `cargo run --release --example pipeline_trace`

fn main() {
    lime::experiments::fig34_schedules(3);
    println!("\nLegend: '#' compute, 'L' SSD load, 'S' SSD store, '~' activation hop, 'K' KV transfer, '.' stall");
    println!("Note how the traditional schedule (Figs 3a/4a) stalls ('.') on every load,");
    println!("while the interleaved schedule hides loads behind other devices' compute.");
}
