//! Extremely-low-memory survival (paper §V-C, Figs 15–17): progressively
//! shrink the cluster memory and watch baselines fall over (OOM/OOT) while
//! LIME keeps serving Llama3.3-70B.
//!
//! Run with: `cargo run --release --example lowmem_survival`

use lime::baselines::all;
use lime::cluster::Cluster;
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::workload::Pattern;

fn main() {
    let spec = ModelSpec::llama33_70b();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let settings = [
        ("Setting 1 (Orin64 + 2xOrin32 + 2xNX16)", Cluster::lowmem_setting1()),
        ("Setting 2 (one NX16 halved to 8 GB)", Cluster::lowmem_setting2()),
        ("Setting 3 (8 GB removed from an Orin32)", Cluster::lowmem_setting3()),
    ];
    for (name, cluster) in settings {
        println!("\n=== {name}: total usable {} ===", lime::util::bytes::fmt_bytes(cluster.total_usable_mem()));
        for method in all() {
            for pattern in [Pattern::Sporadic, Pattern::Bursty] {
                let out = method.run(&spec, &cluster, &bw, pattern, 16);
                let label = match out.ms_per_token() {
                    None => "OOM".to_string(),
                    Some(ms) if ms > pattern.oot_limit_ms() => format!("OOT ({ms:.0} ms/tok)"),
                    Some(ms) => format!("{ms:9.1} ms/tok"),
                };
                println!("  {:32} {:9}  {}", method.name(), format!("{pattern:?}"), label);
            }
        }
    }
}
