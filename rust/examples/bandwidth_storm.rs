//! Bandwidth-storm demo (paper §V-D, Fig. 18): run LIME and the baselines
//! under a random 50–250 Mbps bandwidth walk and show how the online
//! KV-transfer protocol absorbs the fluctuations.
//!
//! Run with: `cargo run --release --example bandwidth_storm`

use lime::baselines::by_name;
use lime::cluster::Cluster;
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::pipeline::{run_interleaved, ExecOptions};
use lime::plan::{plan, PlanOptions};
use lime::util::bytes::mbps;
use lime::workload::Pattern;

fn main() {
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    let tokens = 96;
    let trace = BandwidthTrace::random_walk_mbps(7, 50.0, 250.0, 5, 40, tokens);

    println!("bandwidth walk (first 10 change points):");
    let mut last = -1.0;
    let mut shown = 0;
    for t in 0..tokens {
        let b = trace.at(t);
        if b != last && shown < 10 {
            println!("  token {t:3}: {:.0} Mbps", b * 8.0 / 1e6);
            last = b;
            shown += 1;
        }
    }

    println!("\nmethod performance under the storm (sporadic):");
    for key in ["lime", "lime-no-kv-transfer", "pp-offload", "tpi-llm-offload"] {
        let m = by_name(key).unwrap();
        let out = m.run(&spec, &cluster, &trace, Pattern::Sporadic, tokens);
        match out.ms_per_token() {
            Some(ms) => println!("  {:32} {ms:9.1} ms/token", m.name()),
            None => println!("  {:32} OOM", m.name()),
        }
    }

    // Inside view: how much KV the protocol moved.
    let popts = PlanOptions {
        empirical_tokens: tokens,
        micro_batch: 1,
        bandwidth: mbps(150.0),
    };
    let alloc = plan(&spec, &cluster, &popts).unwrap().allocation;
    let run = run_interleaved(&alloc, &cluster, &trace, 1, tokens, &ExecOptions::default());
    println!(
        "\nLIME internals over {tokens} tokens: {} KV tokens shipped between devices, {} online offload plans fired, {} emergency spills",
        run.kv_tokens_transferred, run.online_plans_fired, run.emergency_steps
    );
}
