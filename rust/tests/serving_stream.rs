//! Continuous-serving invariants over the unified executor core:
//!
//! * a **single-request stream is bit-identical** to the legacy `run_*`
//!   entry point for all three schedule policies — step latencies,
//!   counters, and trace — including under scripted joint pressure for
//!   the interleaved policy (the refactor's acceptance property);
//! * fluctuation scripts fire on the **stream timeline**: an event whose
//!   step index lies beyond the first request lands mid-stream in a later
//!   request, leaving every earlier step bit-identical;
//! * **bursty arrivals queue at least as hard as sporadic arrivals** at
//!   equal request count (the §V-A serving claim the simulator exists to
//!   measure).

use lime::adapt::{MemScenario, Script};
use lime::cluster::Cluster;
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::pipeline::{
    run_interleaved, run_interleaved_scripted, run_tensor_parallel, run_traditional, ExecOptions,
    SimResult, TpOptions, TradOptions,
};
use lime::plan::{plan, Allocation, PlanOptions};
use lime::serve::{serve_interleaved, serve_tensor_parallel, serve_traditional, StreamResult};
use lime::sim::TraceMode;
use lime::util::bytes::{gib, mbps};
use lime::util::prop::{check, pair, usize_in, Config, PropResult};
use lime::workload::{stream_requests, Pattern, Request};

fn setup_small() -> (Allocation, Cluster) {
    let spec = ModelSpec::llama2_13b();
    let cluster = Cluster::env_e1();
    let opts = PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };
    (plan(&spec, &cluster, &opts).unwrap().allocation, cluster)
}

fn setup_lowmem() -> (Allocation, Cluster) {
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    let opts = PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };
    (plan(&spec, &cluster, &opts).unwrap().allocation, cluster)
}

/// `micro` simultaneous zero-time requests, each decoding `tokens` — the
/// stream shape whose single admitted batch must reproduce
/// `run_*(micro, tokens)` bit for bit.
fn batch_requests(micro: usize, tokens: usize) -> Vec<Request> {
    stream_requests(Pattern::Bursty, 0xE0, micro, 1.0, 64, tokens)
}

fn assert_stream_matches_run(sr: &StreamResult, direct: &SimResult, what: &str) {
    assert_eq!(sr.step_times, direct.step_times, "{what}: step latencies");
    assert_eq!(sr.emergency_steps, direct.emergency_steps, "{what}: emergencies");
    assert_eq!(sr.bw_stalls, direct.bw_stalls, "{what}: bw stalls");
    assert_eq!(
        sr.kv_tokens_transferred, direct.kv_tokens_transferred,
        "{what}: kv shipped"
    );
    assert_eq!(
        sr.online_plans_fired, direct.online_plans_fired,
        "{what}: plans fired"
    );
    assert_eq!(
        sr.trace.span_count(),
        direct.trace.span_count(),
        "{what}: span count"
    );
}

#[test]
fn prop_single_batch_stream_is_bit_identical_to_run_interleaved() {
    let (alloc, cluster) = setup_small();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let gen = pair(usize_in(1, 4), usize_in(1, 10));
    let cfg = Config {
        cases: 16,
        seed: 0x57_AE,
        max_shrink_steps: 16,
    };
    let result = check(&cfg, &gen, |&(micro, tokens)| {
        let reqs = batch_requests(micro, tokens);
        let sr = serve_interleaved(&alloc, &cluster, &bw, micro, &opts, &Script::none(), &reqs);
        let direct = run_interleaved(&alloc, &cluster, &bw, micro, tokens, &opts);
        if sr.step_times != direct.step_times {
            return Err(format!(
                "({micro},{tokens}): stream {:?} != direct {:?}",
                sr.step_times, direct.step_times
            ));
        }
        if sr.kv_tokens_transferred != direct.kv_tokens_transferred
            || sr.online_plans_fired != direct.online_plans_fired
            || sr.emergency_steps != direct.emergency_steps
            || sr.bw_stalls != direct.bw_stalls
        {
            return Err(format!("({micro},{tokens}): counters diverged"));
        }
        Ok(())
    });
    assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
}

#[test]
fn single_batch_stream_matches_run_interleaved_with_full_trace() {
    let (alloc, cluster) = setup_small();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = ExecOptions::default(); // TraceMode::Full
    let reqs = batch_requests(2, 6);
    let sr = serve_interleaved(&alloc, &cluster, &bw, 2, &opts, &Script::none(), &reqs);
    let direct = run_interleaved(&alloc, &cluster, &bw, 2, 6, &opts);
    assert_stream_matches_run(&sr, &direct, "interleaved/full-trace");
    assert!(sr.trace.span_count() > 0);
    // Stream metrics line up with the single run: no queueing, TTFT is
    // prefill + first step, finish is the decode end.
    let m = &sr.requests[0];
    assert_eq!(m.queueing_delay, 0.0);
    assert_eq!(sr.makespan, m.finish);
    // finish − ttft spans steps 1..n (arrival is 0), i.e. the decode span
    // minus the first step.
    let decode_after_first = direct.total_time - direct.step_times[0];
    assert!(
        ((m.finish - m.ttft) - decode_after_first).abs() < 1e-9,
        "decode span mismatch: {} vs {}",
        m.finish - m.ttft,
        decode_after_first
    );
}

#[test]
fn single_batch_stream_matches_scripted_run_interleaved() {
    // Scripted joint pressure (memory + bandwidth channels) through the
    // stream path reproduces run_interleaved_scripted bit for bit.
    let (alloc, cluster) = setup_lowmem();
    let bw = BandwidthTrace::fixed_mbps(150.0);
    let opts = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let script = Script::from_mem(MemScenario::squeeze("sq", 0, gib(6.0), 2))
        .with_bandwidth_sag(0.5, 1, 5)
        .with_label("joint");
    for (micro, tokens) in [(1usize, 8usize), (3, 6)] {
        let reqs = batch_requests(micro, tokens);
        let sr = serve_interleaved(&alloc, &cluster, &bw, micro, &opts, &script, &reqs);
        let direct = run_interleaved_scripted(&alloc, &cluster, &bw, micro, tokens, &opts, &script);
        assert_stream_matches_run(&sr, &direct, &format!("scripted ({micro},{tokens})"));
    }
}

#[test]
fn single_batch_stream_is_bit_identical_for_baseline_policies() {
    let (alloc, cluster) = setup_small();
    let spec = alloc.spec.clone();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let trad = TradOptions {
        trace_mode: TraceMode::Off,
        ..TradOptions::default()
    };
    let tp = TpOptions {
        trace_mode: TraceMode::Off,
        ..TpOptions::default()
    };
    for (micro, tokens) in [(1usize, 6usize), (2, 4), (4, 5)] {
        let reqs = batch_requests(micro, tokens);
        let sr = serve_traditional(&alloc, &cluster, &bw, micro, &trad, &Script::none(), &reqs);
        let direct = run_traditional(&alloc, &cluster, &bw, micro, tokens, &trad);
        assert_stream_matches_run(&sr, &direct, &format!("traditional ({micro},{tokens})"));

        let sr = serve_tensor_parallel(&spec, &cluster, &bw, micro, &tp, &Script::none(), &reqs);
        let direct = run_tensor_parallel(&spec, &cluster, &bw, micro, tokens, &tp);
        assert_stream_matches_run(&sr, &direct, &format!("tensor ({micro},{tokens})"));
    }
}

#[test]
fn scripts_apply_on_the_stream_timeline_not_per_request() {
    // Three back-to-back single-request runs of `tokens` steps each; the
    // squeeze lands at stream step `tokens + 1` — inside the SECOND
    // request. Per-request step counters never reach it, so any effect
    // proves the script fired on the stream timeline. Before the event the
    // stream must stay bit-identical to the unscripted one.
    let (alloc, cluster) = setup_lowmem();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let tokens = 4usize;
    let reqs = batch_requests(3, tokens); // all at t=0, served one at a time
    let plain = serve_interleaved(&alloc, &cluster, &bw, 1, &opts, &Script::none(), &reqs);
    let script = Script::from_mem(MemScenario::squeeze("sq", 0, gib(48.0), tokens + 1));
    let squeezed = serve_interleaved(&alloc, &cluster, &bw, 1, &opts, &script, &reqs);
    assert_eq!(plain.batches, 3);
    assert_eq!(squeezed.batches, 3);
    assert_eq!(plain.step_times.len(), 3 * tokens);
    // Request 1 (steps 0..tokens) precedes the event: bit-identical.
    assert_eq!(
        squeezed.step_times[..tokens],
        plain.step_times[..tokens],
        "pre-event steps must not change"
    );
    // The near-total squeeze must visibly disturb the later requests.
    assert!(
        squeezed.step_times != plain.step_times,
        "a 48 GiB squeeze at stream step {} must perturb the stream",
        tokens + 1
    );
    assert!(
        squeezed.emergency_steps > plain.emergency_steps
            || squeezed.online_plans_fired > plain.online_plans_fired,
        "the squeeze must engage adaptation or the emergency fallback \
         (squeezed: {} plans / {} emergencies, plain: {} / {})",
        squeezed.online_plans_fired,
        squeezed.emergency_steps,
        plain.online_plans_fired,
        plain.emergency_steps
    );
}

#[test]
fn prop_bursty_queues_at_least_as_hard_as_sporadic() {
    // §V-A: at equal request count, simultaneous submission (bursty) can
    // only increase queueing over occasional arrivals (sporadic). The
    // sporadic rate is low (mean gap 100 s vs seconds of service), so
    // its queue stays near-empty while the bursty backlog always waits.
    let (alloc, cluster) = setup_small();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let d = cluster.len();
    let gen = pair(usize_in(d + 1, 2 * d + 2), usize_in(0, 1000));
    let cfg = Config {
        cases: 12,
        seed: 0xB0_57,
        max_shrink_steps: 8,
    };
    let result = check(&cfg, &gen, |&(count, salt)| {
        let tokens = 3;
        let seed = 0x5EED ^ salt as u64;
        let bursty_reqs = stream_requests(Pattern::Bursty, seed, count, 0.01, 64, tokens);
        let sporadic_reqs = stream_requests(Pattern::Sporadic, seed, count, 0.01, 64, tokens);
        let bursty =
            serve_interleaved(&alloc, &cluster, &bw, d, &opts, &Script::none(), &bursty_reqs);
        let sporadic = serve_interleaved(
            &alloc,
            &cluster,
            &bw,
            d,
            &opts,
            &Script::none(),
            &sporadic_reqs,
        );
        let (bq, sq) = (bursty.mean_queueing_delay(), sporadic.mean_queueing_delay());
        if bq + 1e-9 < sq {
            return Err(format!(
                "count={count} seed={seed:#x}: bursty mean qd {bq:.3}s < sporadic {sq:.3}s"
            ));
        }
        // count > |D| forces a second bursty batch, so bursty queueing is
        // strictly positive.
        if bq <= 0.0 {
            return Err(format!("count={count}: bursty backlog never queued"));
        }
        Ok(())
    });
    assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
}
