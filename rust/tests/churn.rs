//! Device-churn robustness invariants (CI runs this suite under
//! `LIME_THREADS={1,4}`):
//!
//! * a churn script whose events never fire leaves **every executor and
//!   the serving path bit-identical** to the no-churn run — churn is a
//!   pay-for-what-you-use overlay, never a perturbation;
//! * one composed script (correlated memory dip + bandwidth sag + a
//!   device Down/Up blip) fires pressure adaptation **and** churn
//!   re-planning with KV migration in a single run, and records a
//!   recovery slot per Down event;
//! * a script that takes down the **last surviving device** surfaces as
//!   a structured [`ChurnError`], not a panic;
//! * the churn-capable static baseline (EdgeShard) degrades honestly
//!   under the same fault LIME re-plans around.

use lime::adapt::{MemScenario, Script};
use lime::baselines::{by_name, Outcome};
use lime::cluster::Cluster;
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::pipeline::{
    run_interleaved_scripted, run_single_checked, run_tensor_parallel_scripted,
    run_traditional_scripted, CommonOptions, ExecOptions, InterleavedPolicy, TpOptions,
    TradOptions,
};
use lime::plan::{plan, Allocation, PlanOptions};
use lime::serve::serve_interleaved;
use lime::sim::TraceMode;
use lime::util::bytes::{gib, mbps};
use lime::workload::{stream_requests, Pattern, Request};

fn setup_small() -> (Allocation, Cluster) {
    let spec = ModelSpec::llama2_13b();
    let cluster = Cluster::env_e1();
    let opts = PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };
    (plan(&spec, &cluster, &opts).unwrap().allocation, cluster)
}

fn setup_lowmem() -> (Allocation, Cluster) {
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    let opts = PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };
    (plan(&spec, &cluster, &opts).unwrap().allocation, cluster)
}

fn batch_requests(micro: usize, tokens: usize) -> Vec<Request> {
    stream_requests(Pattern::Bursty, 0xE0, micro, 1.0, 64, tokens)
}

#[test]
fn unfired_churn_leaves_every_executor_bit_identical() {
    // Events scheduled past the horizon never fire; the overlay must be
    // invisible — same timings, same counters, zero churn telemetry.
    let (alloc, cluster) = setup_small();
    let spec = ModelSpec::llama2_13b();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let tokens = 6;
    let late = Script::device_down_up("late-blip", 1, 1_000, 2_000);
    let none = Script::none();

    let exec = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let a = run_interleaved_scripted(&alloc, &cluster, &bw, 1, tokens, &exec, &none);
    let b = run_interleaved_scripted(&alloc, &cluster, &bw, 1, tokens, &exec, &late);
    assert_eq!(a.step_times, b.step_times, "interleaved timings");
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.kv_tokens_transferred, b.kv_tokens_transferred);
    assert_eq!(b.replans_fired, 0);
    assert_eq!(b.kv_migrated_bytes, 0);
    assert!(b.recovery_steps.is_empty());

    let trad = TradOptions {
        trace_mode: TraceMode::Off,
        ..TradOptions::default()
    };
    let a = run_traditional_scripted(&alloc, &cluster, &bw, 1, tokens, &trad, &none);
    let b = run_traditional_scripted(&alloc, &cluster, &bw, 1, tokens, &trad, &late);
    assert_eq!(a.step_times, b.step_times, "traditional timings");
    assert_eq!(a.total_time, b.total_time);

    let tp = TpOptions {
        trace_mode: TraceMode::Off,
        ..TpOptions::default()
    };
    let a = run_tensor_parallel_scripted(&spec, &cluster, &bw, 1, tokens, &tp, &none);
    let b = run_tensor_parallel_scripted(&spec, &cluster, &bw, 1, tokens, &tp, &late);
    assert_eq!(a.step_times, b.step_times, "tensor-parallel timings");
    assert_eq!(a.total_time, b.total_time);

    // Serving path: the whole stream, not just one request.
    let reqs = batch_requests(2, 4);
    let sa = serve_interleaved(&alloc, &cluster, &bw, 2, &exec, &none, &reqs);
    let sb = serve_interleaved(&alloc, &cluster, &bw, 2, &exec, &late, &reqs);
    assert_eq!(sa.step_times, sb.step_times, "stream timings");
    assert_eq!(sa.makespan, sb.makespan);
    assert_eq!(sb.replans_fired, 0);
    assert!(sb.recovery_steps.is_empty());
}

#[test]
fn composed_pressure_and_churn_fire_adaptation_and_migration_in_one_run() {
    // One script carrying all three channels: the correlated dip +
    // bandwidth sag drive LIME's online pressure machinery while the
    // Down/Up blip of the smallest device forces a churn re-plan and a
    // KV migration — in the same run, on the lowmem 70B deployment.
    let (alloc, cluster) = setup_lowmem();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let tokens = 48;
    let last = cluster.len() - 1;
    let script = Script::from_mem(MemScenario::correlated_dip(
        "corr-dip-d01",
        &[0, 1],
        2,
        gib(4.0),
        8,
        40,
    ))
    .with_bandwidth_sag(0.5, 8, 40)
    .with_device_down_up(last, 16, 32)
    .with_label("joint-pressure-churn");

    let exec = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let r = run_interleaved_scripted(&alloc, &cluster, &bw, 1, tokens, &exec, &script);
    assert!(
        r.online_plans_fired > 0 || r.emergency_steps > 0,
        "memory pressure must fire the online adaptation"
    );
    assert!(r.replans_fired >= 1, "the Down/Up blip must fire a re-plan");
    assert!(r.kv_migrated_bytes > 0, "the departing device's KV must migrate");
    assert_eq!(r.recovery_steps.len(), 1, "one Down event, one recovery slot");
    // The fault window really costs something: the churned run is no
    // faster than the same pressure script without the blip.
    let pressure_only = Script::from_mem(MemScenario::correlated_dip(
        "corr-dip-d01",
        &[0, 1],
        2,
        gib(4.0),
        8,
        40,
    ))
    .with_bandwidth_sag(0.5, 8, 40);
    let p = run_interleaved_scripted(&alloc, &cluster, &bw, 1, tokens, &exec, &pressure_only);
    assert!(r.total_time >= p.total_time, "churn cannot make the run faster");
}

#[test]
fn taking_down_the_last_device_is_a_structured_error() {
    // A single-device deployment whose only device goes down: the checked
    // entry point must return the typed error (the unchecked run_* family
    // documents the panic), naming the step and device.
    let spec = ModelSpec::llama2_13b();
    let cluster = Cluster::env_e1().subset(&[0]);
    let popts = PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };
    let alloc = plan(&spec, &cluster, &popts).unwrap().allocation;
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let exec = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let script = Script::device_down_up("kill-d0", 0, 2, 4);
    let err = run_single_checked(
        InterleavedPolicy::new(&alloc, &cluster, &exec),
        &cluster,
        &bw,
        1,
        6,
        &CommonOptions::from(&exec),
        &script,
    )
    .expect_err("downing the only device must fail");
    assert_eq!(err.device, 0);
    assert_eq!(err.at_step, 2);
    assert!(err.to_string().contains("no surviving devices"));
}

#[test]
fn edgeshard_degrades_under_the_fault_lime_replans_around() {
    // The honest-degradation contract: EdgeShard's static partition rides
    // the churn axis (zeroed caps, emergency spills) without any of
    // LIME's recovery machinery, while LIME re-plans onto the survivors.
    let spec = ModelSpec::llama2_13b();
    let cluster = Cluster::env_e1();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let tokens = 24;
    let script = Script::device_down_up("d1-blip", 1, 8, 16);

    let es = by_name("edgeshard").unwrap();
    assert!(es.churn_capable());
    let base = es.run_mode(&spec, &cluster, &bw, Pattern::Sporadic, tokens, TraceMode::Off);
    let churned =
        es.run_scripted(&spec, &cluster, &bw, Pattern::Sporadic, tokens, TraceMode::Off, &script);
    let (Outcome::Ok(b), Outcome::Ok(c)) = (base, churned) else {
        panic!("EdgeShard must complete on E1 with and without churn");
    };
    assert!(
        c.total_time >= b.total_time,
        "a static partition cannot get faster when a device dies: {} < {}",
        c.total_time,
        b.total_time
    );
    assert_eq!(c.replans_fired, 0, "no re-planning machinery");
    assert_eq!(c.kv_migrated_bytes, 0, "no migration machinery");
    assert_eq!(c.recovery_steps.len(), 1, "the core still tracks recovery");

    // Rigid baselines without the capability stay off the axis entirely:
    // run_scripted falls back to the unscripted run.
    let galaxy = by_name("galaxy").unwrap();
    assert!(!galaxy.churn_capable());
    let g0 = galaxy.run_mode(&spec, &cluster, &bw, Pattern::Sporadic, tokens, TraceMode::Off);
    let g1 = galaxy
        .run_scripted(&spec, &cluster, &bw, Pattern::Sporadic, tokens, TraceMode::Off, &script);
    assert_eq!(g0.ms_per_token(), g1.ms_per_token());

    // LIME on the same fault: re-plan fired, KV migrated, recovery slot
    // recorded (finite once the device returns and latency settles).
    let (alloc, cluster) = setup_small();
    let exec = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let r = run_interleaved_scripted(&alloc, &cluster, &bw, 1, tokens, &exec, &script);
    assert!(r.replans_fired >= 1);
    assert_eq!(r.recovery_steps.len(), 1);
}
