//! Cross-module integration tests: scheduler ↔ cost model ↔ simulator ↔
//! adaptation, plus property tests on scheduler invariants via the
//! in-repo `util::prop` framework.

use lime::baselines::{by_name, Outcome};
use lime::cluster::{Cluster, DeviceSpec};
use lime::cost;
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::pipeline::{run_interleaved, ExecOptions};
use lime::plan::{plan, PlanOptions};
use lime::sim::SpanKind;
use lime::util::bytes::{gib, mbps};
use lime::util::prop::{assert_prop, pair, usize_in, vec_of, Gen};
use lime::workload::Pattern;

fn opts() -> PlanOptions {
    PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    }
}

// ------------------------------------------------------------ end-to-end

#[test]
fn cost_model_predicts_simulator_within_2x() {
    // Eq. 1 and the DES implement the same overlap structure: per-token
    // predictions must agree to within a small factor (the DES adds
    // queueing and online effects the closed form ignores).
    for cluster in [Cluster::env_e3(), Cluster::lowmem_setting1()] {
        let spec = ModelSpec::llama33_70b();
        let report = plan(&spec, &cluster, &opts()).unwrap();
        let predicted = report.cost.total();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let sim = run_interleaved(
            &report.allocation,
            &cluster,
            &bw,
            1,
            24,
            &ExecOptions::default(),
        );
        let measured = sim.mean_step();
        let ratio = measured / predicted;
        assert!(
            (0.5..2.0).contains(&ratio),
            "prediction {predicted:.3}s vs simulation {measured:.3}s (ratio {ratio:.2})"
        );
    }
}

#[test]
fn uncovered_load_in_trace_matches_cost_model_direction() {
    // Where Eq. 1 says loads are fully covered, the trace must show little
    // uncovered load time; where it predicts uncovered time, the trace
    // must show it.
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting2();
    let report = plan(&spec, &cluster, &opts()).unwrap();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let sim = run_interleaved(&report.allocation, &cluster, &bw, 1, 12, &ExecOptions::default());
    let uncovered_trace: f64 = (0..cluster.len())
        .map(|i| sim.trace.uncovered_load(i))
        .fold(0.0, f64::max);
    if report.cost.t_uncover > 0.1 {
        assert!(
            uncovered_trace > 0.0,
            "cost model predicts {:.2}s uncovered but trace shows none",
            report.cost.t_uncover
        );
    }
}

#[test]
fn all_methods_complete_or_oom_cleanly_everywhere() {
    // Failure-injection sweep: no method may panic on any (env, model,
    // pattern, bandwidth) combination — they either run or report OOM.
    let combos: Vec<(ModelSpec, Cluster)> = vec![
        (ModelSpec::llama2_13b(), Cluster::env_e1()),
        (ModelSpec::qwen3_32b(), Cluster::env_e2()),
        (ModelSpec::llama33_70b(), Cluster::lowmem_setting3()),
    ];
    for (spec, cluster) in &combos {
        for key in [
            "lime",
            "pp",
            "pp-offload",
            "edgeshard",
            "galaxy",
            "tpi-llm",
            "tpi-llm-offload",
        ] {
            let m = by_name(key).unwrap();
            for pattern in [Pattern::Sporadic, Pattern::Bursty] {
                for bw in [50.0, 250.0] {
                    let trace = BandwidthTrace::fixed_mbps(bw);
                    match m.run(spec, cluster, &trace, pattern, 6) {
                        Outcome::Ok(r) => {
                            assert!(r.ms_per_token().is_finite());
                            assert!(r.ms_per_token() > 0.0);
                        }
                        Outcome::Oom(msg) => assert!(!msg.is_empty()),
                    }
                }
            }
        }
    }
}

#[test]
fn lime_never_ooms_when_aggregate_memory_suffices() {
    // LIME's promise: as long as slots + embed fit, it serves the model.
    let spec = ModelSpec::llama33_70b();
    for cluster in [
        Cluster::env_e3(),
        Cluster::lowmem_setting1(),
        Cluster::lowmem_setting2(),
        Cluster::lowmem_setting3(),
    ] {
        let m = by_name("lime").unwrap();
        let trace = BandwidthTrace::fixed_mbps(100.0);
        let out = m.run(&spec, &cluster, &trace, Pattern::Sporadic, 6);
        assert!(out.ms_per_token().is_some(), "LIME OOMed on a feasible cluster");
    }
}

#[test]
fn online_adaptation_engages_on_long_runs() {
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    let report = plan(&spec, &cluster, &opts()).unwrap();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    // 5 micro-batches x 1200 steps: KV far outgrows the 128-token reserve.
    let sim = run_interleaved(&report.allocation, &cluster, &bw, 5, 1200, &ExecOptions::default());
    assert!(
        sim.online_plans_fired > 0 || sim.kv_tokens_transferred > 0,
        "no adaptation fired: plans={} transfers={}",
        sim.online_plans_fired,
        sim.kv_tokens_transferred
    );
}

#[test]
fn trace_spans_are_well_formed() {
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting2();
    let report = plan(&spec, &cluster, &opts()).unwrap();
    let bw = BandwidthTrace::fixed_mbps(150.0);
    let sim = run_interleaved(&report.allocation, &cluster, &bw, 2, 8, &ExecOptions::default());
    assert!(sim.trace.span_count() > 0);
    for (device, s) in sim.trace.spans() {
        assert!(s.end >= s.start, "span {s:?} ends before start");
        assert!(device < cluster.len());
    }
    // Compute must appear on every device that owns layers.
    for i in 0..cluster.len() {
        if report.allocation.devices[i].total_layers > 0 {
            assert!(sim.trace.busy(i, SpanKind::Compute) > 0.0, "device {i} never computed");
        }
    }
}

// --------------------------------------------------------- property tests

#[test]
fn prop_plans_cover_model_and_fit_memory() {
    // Random heterogeneous clusters: whenever the scheduler returns a plan
    // it covers every layer exactly once and satisfies Eq. 1's memory
    // constraint at the empirical token count.
    let dev_gen: Gen<usize> = usize_in(0, 2); // index into device presets
    let cluster_gen = vec_of(dev_gen, 2, 5);
    let gen = pair(cluster_gen, usize_in(0, 2));
    assert_prop("plan covers model & fits", &gen, |(devs, model_idx)| {
        let devices: Vec<DeviceSpec> = devs
            .iter()
            .map(|&k| match k {
                0 => DeviceSpec::xavier_nx_16(),
                1 => DeviceSpec::agx_orin_32(),
                _ => DeviceSpec::agx_orin_64(),
            })
            .collect();
        let cluster = Cluster::new(devices);
        let spec = match model_idx {
            0 => ModelSpec::llama2_13b(),
            1 => ModelSpec::qwen3_32b(),
            _ => ModelSpec::llama33_70b(),
        };
        match plan(&spec, &cluster, &opts()) {
            Err(_) => Ok(()), // OOM is a legal outcome
            Ok(report) => {
                if !report.allocation.covers_model() {
                    return Err(format!(
                        "layers {} != {}",
                        report.allocation.layer_sum(),
                        spec.layers
                    ));
                }
                cost::feasible(&report.allocation, &cluster, 128)
                    .map_err(|e| format!("infeasible plan: {e}"))
            }
        }
    });
}

#[test]
fn prop_memory_limits_monotone_homogeneous() {
    // On a *homogeneous* cluster, shrinking one device's memory never
    // improves the planned cost (no compute-rebalancing upside exists —
    // only more offloading). NB: heterogeneous clusters genuinely violate
    // this (shrinking a slow device shifts layers to faster ones).
    let gen = pair(usize_in(2, 30), usize_in(0, 2));
    assert_prop("mem shrink never helps", &gen, |&(mem_gb, which)| {
        let spec = ModelSpec::qwen3_32b();
        let full = Cluster::new(vec![
            lime::cluster::DeviceSpec::agx_orin_32(),
            lime::cluster::DeviceSpec::agx_orin_32(),
            lime::cluster::DeviceSpec::agx_orin_32(),
        ]);
        let mut shrunk = full.clone();
        let idx = which.min(shrunk.len() - 1);
        shrunk.devices[idx] = shrunk.devices[idx].clone().with_mem_limit(gib(mem_gb as f64));
        let o = opts();
        match (plan(&spec, &full, &o), plan(&spec, &shrunk, &o)) {
            (Ok(a), Ok(b)) => {
                if b.cost.total() + 1e-9 >= a.cost.total() {
                    Ok(())
                } else {
                    Err(format!(
                        "shrunk cluster cheaper: {:.3} < {:.3}",
                        b.cost.total(),
                        a.cost.total()
                    ))
                }
            }
            (Ok(_), Err(_)) => Ok(()), // shrinking to OOM is legal
            (Err(_), _) => Ok(()),
        }
    });
}

#[test]
fn prop_bandwidth_monotone_for_lime() {
    // More bandwidth never makes LIME slower (same plan, same seed).
    let gen = usize_in(50, 250);
    assert_prop("bandwidth monotone", &gen, |&lo_mbps| {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting1();
        let report = plan(&spec, &cluster, &opts()).unwrap();
        let lo = run_interleaved(
            &report.allocation,
            &cluster,
            &BandwidthTrace::fixed_mbps(lo_mbps as f64),
            1,
            6,
            &ExecOptions::default(),
        );
        let hi = run_interleaved(
            &report.allocation,
            &cluster,
            &BandwidthTrace::fixed_mbps(lo_mbps as f64 + 100.0),
            1,
            6,
            &ExecOptions::default(),
        );
        if hi.ms_per_token() <= lo.ms_per_token() * 1.001 {
            Ok(())
        } else {
            Err(format!(
                "bw {} -> {:.1} ms but bw {} -> {:.1} ms",
                lo_mbps,
                lo.ms_per_token(),
                lo_mbps + 100,
                hi.ms_per_token()
            ))
        }
    });
}

#[test]
fn prop_segment_counts_within_bounds() {
    // Eq. 1 constraint: 2 <= #Seg <= ceil(|L|/|D|) whenever offload engaged.
    let gen = usize_in(0, 2);
    assert_prop("seg bounds", &gen, |&setting| {
        let spec = ModelSpec::llama33_70b();
        let cluster = match setting {
            0 => Cluster::lowmem_setting1(),
            1 => Cluster::lowmem_setting2(),
            _ => Cluster::lowmem_setting3(),
        };
        let Ok(report) = plan(&spec, &cluster, &opts()) else {
            return Ok(());
        };
        let alloc = &report.allocation;
        let offloaded: usize = alloc.devices.iter().map(|d| d.offloaded_count()).sum();
        if offloaded == 0 {
            return Ok(()); // degenerate plain pipeline is fine
        }
        let max = spec.layers.div_ceil(cluster.len()).max(2);
        if (2..=max).contains(&alloc.seg) {
            Ok(())
        } else {
            Err(format!("seg {} outside 2..={max}", alloc.seg))
        }
    });
}
