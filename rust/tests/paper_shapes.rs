//! Reproduction-shape tests: the qualitative claims of every paper
//! figure/table, asserted end-to-end through the experiment harness.
//! Absolute ms/token are testbed-specific; these tests pin down *who wins,
//! by roughly what factor, and where the failure modes (OOM/OOT) land*.

use lime::experiments;
use lime::workload::Pattern;

#[test]
fn fig2a_shape_pp_beats_tp_with_offloading() {
    // §III / Fig. 2a: PP+offload beats TP+offload at 200 Mbps on every
    // tested (model, setting) pair — the paper reports 1.2x-1.6x.
    for (label, tp, pp) in experiments::fig2a(12) {
        assert!(pp < tp, "{label}: PP {pp:.1} !< TP {tp:.1}");
    }
}

#[test]
fn fig2b_shape_kv_offload_crosses_model_shard() {
    // §III / Fig. 2b: KV offload starts cheaper per step, but growing,
    // jittery writes push it above the stable model-shard read.
    let rows = experiments::fig2b(500);
    assert!(rows[0].2 < rows[0].1, "KV should start cheaper");
    let tail = &rows[rows.len() - 50..];
    let tail_kv: f64 = tail.iter().map(|r| r.2).sum::<f64>() / 50.0;
    let tail_model: f64 = tail.iter().map(|r| r.1).sum::<f64>() / 50.0;
    assert!(
        tail_kv > tail_model,
        "late KV ({tail_kv:.2} ms) should exceed model-shard ({tail_model:.2} ms)"
    );
}

#[test]
fn fig34_shape_interleaved_hides_loads() {
    let (trad_s, lime_s, _trad_b, _lime_b) = experiments::fig34_schedules(2);
    // The traditional schedule must show stalls; both must show loads.
    assert!(trad_s.contains('L'), "traditional trace shows no loads");
    assert!(lime_s.contains('L'), "interleaved trace shows no loads");
}

#[test]
fn fig78_shape_extreme_segment_counts_lose() {
    // Figs 7-8: the best #Seg is interior-or-boundary, and the worst
    // candidate is measurably worse than the best.
    let rows = experiments::fig78_segments(12);
    assert!(rows.len() >= 3, "need several feasible segment counts");
    let best = rows.iter().map(|r| r.1).fold(f64::INFINITY, f64::min);
    let worst = rows.iter().map(|r| r.1).fold(0.0, f64::max);
    assert!(
        worst > best * 1.02,
        "segment count made no difference: best {best:.1}, worst {worst:.1}"
    );
}

#[test]
fn fig14_shape_lime_wins_e3() {
    // Fig. 14: on E3/Llama3.3-70B LIME has the lowest latency among
    // completing methods in every (bandwidth, pattern) column.
    let cells = experiments::main_comparison("e3", 24);
    for &bw in &[100.0, 200.0] {
        for pattern in [Pattern::Sporadic, Pattern::Bursty] {
            let lime = cells
                .iter()
                .find(|c| c.method == "LIME" && c.bandwidth_mbps == bw && c.pattern == pattern)
                .and_then(|c| c.ms_per_token)
                .expect("LIME must complete E3");
            for c in cells
                .iter()
                .filter(|c| c.method != "LIME" && c.bandwidth_mbps == bw && c.pattern == pattern)
            {
                if let Some(ms) = c.ms_per_token {
                    assert!(
                        lime <= ms * 1.001,
                        "{} @{bw} {:?}: LIME {lime:.1} !<= {ms:.1}",
                        c.method,
                        pattern
                    );
                }
            }
        }
    }
}

#[test]
fn fig12_shape_lime_wins_e1() {
    let cells = experiments::main_comparison("e1", 24);
    for pattern in [Pattern::Sporadic, Pattern::Bursty] {
        let lime = cells
            .iter()
            .find(|c| c.method == "LIME" && c.bandwidth_mbps == 200.0 && c.pattern == pattern)
            .and_then(|c| c.ms_per_token)
            .expect("LIME must complete E1");
        for c in cells
            .iter()
            .filter(|c| c.method != "LIME" && c.bandwidth_mbps == 200.0 && c.pattern == pattern)
        {
            if let Some(ms) = c.ms_per_token {
                assert!(lime <= ms * 1.001, "{}: {lime:.1} !<= {ms:.1}", c.method);
            }
        }
    }
}

#[test]
fn fig15_17_shape_failure_modes() {
    // Figs 15-17: rigid methods OOM in every low-memory setting; LIME
    // completes everywhere, and TP-with-offload degrades hard relative to
    // LIME under sporadic requests (the paper's OOT mechanism).
    for setting in 1..=3 {
        let cells = experiments::lowmem(setting, 12);
        for rigid in ["Galaxy", "EdgeShard", "Pipeline parallelism"] {
            assert!(
                cells
                    .iter()
                    .filter(|c| c.method == rigid)
                    .all(|c| c.ms_per_token.is_none()),
                "setting {setting}: {rigid} should OOM"
            );
        }
        let lime_spor = cells
            .iter()
            .find(|c| {
                c.method == "LIME" && c.pattern == Pattern::Sporadic && c.bandwidth_mbps == 200.0
            })
            .and_then(|c| c.ms_per_token)
            .expect("LIME completes");
        let tpi_spor = cells
            .iter()
            .find(|c| {
                c.method == "TPI-LLM + offloading"
                    && c.pattern == Pattern::Sporadic
                    && c.bandwidth_mbps == 200.0
            })
            .and_then(|c| c.ms_per_token)
            .expect("TPI-LLM+offload completes");
        assert!(
            tpi_spor > 2.0 * lime_spor,
            "setting {setting}: TPI-LLM {tpi_spor:.0} should degrade >=2x vs LIME {lime_spor:.0}"
        );
    }
}

#[test]
fn fig18_shape_lime_fastest_under_varying_bandwidth() {
    let cells = experiments::fig18(48);
    for pattern in [Pattern::Sporadic, Pattern::Bursty] {
        let lime = cells
            .iter()
            .find(|c| c.method == "LIME" && c.pattern == pattern)
            .and_then(|c| c.ms_per_token)
            .expect("LIME completes fig18");
        for c in cells.iter().filter(|c| c.method != "LIME" && c.pattern == pattern) {
            if let Some(ms) = c.ms_per_token {
                assert!(
                    lime <= ms * 1.001,
                    "{} {:?}: LIME {lime:.1} !<= {ms:.1}",
                    c.method,
                    pattern
                );
            }
        }
    }
}

#[test]
fn tab5_shape_component_ordering() {
    // Table V: removing the planner hurts more than removing KV transfer;
    // full LIME is fastest (paper: 0.67x/0.69x vs 0.86x/0.87x).
    let rows = experiments::tab5(2048);
    let (no_kv_s, _no_kv_b) = (rows[0].1.unwrap(), rows[0].2.unwrap());
    let (no_pl_s, _no_pl_b) = (rows[1].1.unwrap(), rows[1].2.unwrap());
    let (lime_s, lime_b) = (rows[2].1.unwrap(), rows[2].2.unwrap());
    assert!(lime_s <= no_kv_s * 1.005, "LIME {lime_s:.1} vs no-KV {no_kv_s:.1}");
    assert!(lime_s <= no_pl_s * 1.005, "LIME {lime_s:.1} vs no-planner {no_pl_s:.1}");
    assert!(
        no_pl_s >= no_kv_s,
        "planner ablation ({no_pl_s:.1}) should hurt at least as much as KV ablation ({no_kv_s:.1})"
    );
    assert!(lime_b > 0.0);
}
