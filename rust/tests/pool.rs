//! Work-stealing pool invariants (the tentpole determinism contract):
//!
//! * `Pool::map_indexed` is bit-identical to the sequential loop at any
//!   worker count — including under *nested* submission (a job fanning out
//!   again on the same pool), the shape a grid cell calling `plan()` takes.
//! * A panicking job propagates to its submitting call and poisons nothing:
//!   the pool's workers survive and later sweeps run normally.
//! * A real `experiments` grid evaluated on the pool equals the sequential
//!   reference cell-for-cell, and the executors' sweep entry points equal
//!   their sequential loops.

use lime::baselines::all;
use lime::cluster::Cluster;
use lime::experiments::{grid_cells, grid_cells_sequential};
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::pipeline::{
    run_interleaved, run_tensor_parallel, run_traditional, sweep_interleaved,
    sweep_tensor_parallel, sweep_traditional, ExecOptions, TpOptions, TradOptions,
};
use lime::plan::{plan_on_pool, PlanOptions};
use lime::sim::TraceMode;
use lime::util::bytes::mbps;
use lime::util::pool::Pool;
use lime::util::prop::{check, pair, usize_in, Config, PropResult};

#[test]
fn prop_nested_submission_is_deterministic_at_1_2_8_workers() {
    // Random (outer width, inner width, payload) shapes; every worker
    // count must reproduce the plain nested-loop result exactly.
    let pools = [Pool::new(1), Pool::new(2), Pool::new(8)];
    let gen = pair(pair(usize_in(1, 12), usize_in(1, 10)), usize_in(0, 1000));
    let cfg = Config {
        cases: 24,
        seed: 0x900_1,
        max_shrink_steps: 32,
    };
    let result = check(&cfg, &gen, |&((outer_n, inner_n), salt)| {
        let outer: Vec<usize> = (0..outer_n).collect();
        let want: Vec<u64> = outer
            .iter()
            .map(|&o| {
                (0..inner_n)
                    .map(|i| (o as u64 + 1) * (i as u64 + salt as u64))
                    .sum()
            })
            .collect();
        for pool in &pools {
            let got = pool.map_indexed(&outer, |&o| {
                let inner: Vec<usize> = (0..inner_n).collect();
                pool.map_indexed(&inner, |&i| (o as u64 + 1) * (i as u64 + salt as u64))
                    .into_iter()
                    .sum::<u64>()
            });
            if got != want {
                return Err(format!(
                    "{} workers: {got:?} != {want:?}",
                    pool.workers()
                ));
            }
        }
        Ok(())
    });
    assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
}

#[test]
fn prop_plan_on_pool_matches_sequential_at_1_2_8_workers() {
    // The planner's #Seg candidates as nested pool jobs: the chosen
    // allocation, cost and curve must equal the sequential reference.
    let spec = ModelSpec::llama33_70b();
    let opts = PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };
    for cluster in [Cluster::lowmem_setting1(), Cluster::lowmem_setting3()] {
        let seq = plan_on_pool(&spec, &cluster, &opts, None).expect("sequential plan");
        for workers in [1usize, 2, 8] {
            let pool = Pool::new(workers);
            let par = plan_on_pool(&spec, &cluster, &opts, Some(&pool)).expect("pooled plan");
            assert_eq!(seq.allocation, par.allocation, "workers={workers}");
            assert_eq!(seq.seg_curve, par.seg_curve, "workers={workers}");
            assert_eq!(seq.cost, par.cost, "workers={workers}");
        }
    }
}

#[test]
fn panic_in_job_propagates_but_does_not_poison_the_pool() {
    let pool = Pool::new(4);
    let jobs: Vec<usize> = (0..64).collect();
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.map_indexed(&jobs, |&x| {
            if x == 9 {
                panic!("injected failure in job {x}");
            }
            x * 2
        })
    }));
    assert!(outcome.is_err(), "the job panic must reach the caller");
    // Poisoning check: the same pool still completes real planning work.
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    let opts = PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };
    let after = plan_on_pool(&spec, &cluster, &opts, Some(&pool)).expect("pool survived");
    let reference = plan_on_pool(&spec, &cluster, &opts, None).unwrap();
    assert_eq!(after.allocation, reference.allocation);
}

#[test]
fn pool_grid_equals_sequential_grid_over_real_experiments() {
    // The acceptance check: a real (method × bandwidth × pattern) grid —
    // LIME cells nest plan() onto the pool — must be bit-identical to the
    // sequential triple loop, cell for cell.
    let spec = ModelSpec::llama2_13b();
    let cluster = Cluster::env_e1();
    let methods = all();
    let bandwidths = [100.0, 200.0];
    let pooled = grid_cells(&spec, &cluster, &methods, &bandwidths, 4);
    let sequential = grid_cells_sequential(&spec, &cluster, &methods, &bandwidths, 4);
    assert_eq!(pooled.len(), sequential.len());
    assert_eq!(pooled.len(), methods.len() * bandwidths.len() * 2);
    for (p, s) in pooled.iter().zip(&sequential) {
        assert_eq!(p, s, "grid cell diverged between pool and sequential");
    }
}

#[test]
fn scenario_matrix_pool_equals_sequential() {
    // The scenario-matrix acceptance check: a matrix exercising ALL new
    // axes — #Seg overrides (nested plan_with_segs on the pool), a
    // correlated multi-device dip, a joint bandwidth+memory script, a
    // continuous-stream arrival point, a device-churn blip (online
    // re-plan + KV migration inside the cell), a continuous-batching
    // point (paged-KV accounting inside the cell) and a bimodal
    // workload-mix point (ragged per-request lengths inside the cell),
    // both patterns — must be bit-identical between the pooled
    // evaluation and the sequential reference, cell for cell
    // (request-level metric and length arrays, churn and paged-KV
    // counters included), and the serialized lime-sweep-v7 artifact
    // must be byte-identical (the in-process proxy for CI's
    // LIME_THREADS={1,4} sweep-determinism gate).
    use lime::adapt::{MemScenario, Script};
    use lime::experiments::{ArrivalSpec, BatchingSpec, ScenarioMatrix, SegChoice};
    use lime::util::bytes::gib;
    use lime::workload::{LengthDist, Pattern};

    let methods = all();
    let matrix = ScenarioMatrix::new(
        "pool-vs-seq",
        ModelSpec::llama2_13b(),
        Cluster::env_e1(),
        &methods,
        vec![100.0, 200.0],
        vec![Pattern::Sporadic, Pattern::Bursty],
        4,
    )
    .with_segs(vec![SegChoice::Auto, SegChoice::Fixed(4)])
    .with_pressure(vec![
        Script::none(),
        Script::from_mem(MemScenario::correlated_dip(
            "corr-dip",
            &[0, 1],
            1,
            gib(4.0),
            1,
            3,
        )),
        Script::from_mem(MemScenario::squeeze("sq", 0, gib(4.0), 1))
            .with_bandwidth_sag(0.5, 1, 3)
            .with_label("joint"),
    ])
    .with_arrivals(vec![
        ArrivalSpec::Single,
        ArrivalSpec::Stream {
            count: 4,
            lambda: 0.5,
        },
    ])
    .with_churn(vec![
        Script::none(),
        Script::device_down_up("blip-d1", 1, 1, 3),
    ])
    .with_batching(vec![BatchingSpec::Fifo, BatchingSpec::Continuous { page_tokens: 16 }])
    .with_workloads(vec![
        LengthDist::fixed(64, 4),
        LengthDist::Bimodal {
            short: (32, 2),
            long: (128, 8),
            long_frac: 0.5,
        },
    ]);
    let pooled = matrix.eval();
    let sequential = matrix.eval_sequential();
    assert_eq!(pooled.len(), matrix.cell_count());
    assert_eq!(pooled.len(), sequential.len());
    for (p, s) in pooled.iter().zip(&sequential) {
        assert_eq!(p, s, "scenario cell diverged between pool and sequential");
    }
    // Stream cells really evaluated on both paths (non-trivial arrays).
    assert!(pooled
        .iter()
        .any(|c| c.requests.as_ref().is_some_and(|r| r.ttft_s.len() == 4)));
    // Churn cells really fired on both paths (non-trivial counters).
    assert!(pooled
        .iter()
        .any(|c| c.churn == "blip-d1" && c.ms_per_token.is_some()));
    // Continuous-batching cells really accounted pages on both paths.
    assert!(pooled
        .iter()
        .any(|c| c.batching == "cont16" && c.kv_pages_allocated.unwrap_or(0) > 0));
    // Mixed-workload cells really drew ragged lengths on both paths.
    assert!(pooled.iter().any(|c| c
        .requests
        .as_ref()
        .is_some_and(|r| r.prompt_len.contains(&32) && r.prompt_len.contains(&128))));
    assert_eq!(
        matrix.to_json(&pooled).to_string(),
        matrix.to_json(&sequential).to_string(),
        "serialized v7 artifact must be byte-identical"
    );
}

#[test]
fn executor_sweep_entry_point_matches_sequential_runs() {
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    let opts = PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };
    let alloc = lime::plan::plan(&spec, &cluster, &opts).unwrap().allocation;
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let exec = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let scenarios: Vec<(usize, usize)> = vec![(1, 6), (2, 5), (5, 4), (1, 8)];
    let swept = sweep_interleaved(&alloc, &cluster, &bw, &scenarios, &exec);
    assert_eq!(swept.len(), scenarios.len());
    for (r, &(micro, tokens)) in swept.iter().zip(&scenarios) {
        let direct = run_interleaved(&alloc, &cluster, &bw, micro, tokens, &exec);
        assert_eq!(r.total_time, direct.total_time, "({micro},{tokens})");
        assert_eq!(r.step_times, direct.step_times, "({micro},{tokens})");
        assert_eq!(r.emergency_steps, direct.emergency_steps);
    }

    // Same bit-identity contract for the other two executors' entry points.
    let trad = TradOptions {
        trace_mode: TraceMode::Off,
        ..TradOptions::default()
    };
    let trad_swept = sweep_traditional(&alloc, &cluster, &bw, &scenarios, &trad);
    for (r, &(micro, tokens)) in trad_swept.iter().zip(&scenarios) {
        let direct = run_traditional(&alloc, &cluster, &bw, micro, tokens, &trad);
        assert_eq!(r.total_time, direct.total_time, "trad ({micro},{tokens})");
        assert_eq!(r.step_times, direct.step_times, "trad ({micro},{tokens})");
    }
    let tp = TpOptions {
        trace_mode: TraceMode::Off,
        ..TpOptions::default()
    };
    let tp_swept = sweep_tensor_parallel(&spec, &cluster, &bw, &scenarios, &tp);
    for (r, &(micro, tokens)) in tp_swept.iter().zip(&scenarios) {
        let direct = run_tensor_parallel(&spec, &cluster, &bw, micro, tokens, &tp);
        assert_eq!(r.total_time, direct.total_time, "tp ({micro},{tokens})");
        assert_eq!(r.step_times, direct.step_times, "tp ({micro},{tokens})");
    }
}
