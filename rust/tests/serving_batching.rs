//! Batching-policy contracts of the serving simulator (`serve::simqueue`
//! + `serve::kvpages`, see `docs/SERVING.md`):
//!
//! * **FIFO equivalence**: the continuous driver with `max_batch = 1` and
//!   `prefill_ahead = 0` is bit-identical to the FIFO driver — per-request
//!   metrics and every aggregate — property-tested over random streams of
//!   both arrival patterns (the ISSUE's batch-size-1 acceptance pin), on
//!   fixed-length and bimodal mixed-length streams alike.
//! * **Queueing improvement**: under bursty arrivals with more requests
//!   than batch slots, step-level continuous batching strictly lowers the
//!   mean queueing delay vs FIFO (pinned on a concrete stream), and never
//!   raises it (property over random bursty streams).
//! * **Paged KV**: the sweep's `KvPageConfig` carries exactly the Eq. 8
//!   per-device byte scales, and a budget-starved continuous run really
//!   spills pages and pays for them in stream time.
//!
//! This suite runs in CI's LIME_THREADS={1,4} determinism matrix: nothing
//! here may depend on worker count.

use lime::adapt::{resident_kv_bytes, Script};
use lime::cluster::Cluster;
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::pipeline::ExecOptions;
use lime::plan::{plan, Allocation, PlanOptions};
use lime::serve::{serve_interleaved, serve_interleaved_opts, BatchingOpts, KvPageConfig};
use lime::sim::TraceMode;
use lime::util::bytes::mbps;
use lime::util::prop::{check, pair, usize_in, Config, PropResult};
use lime::workload::{stream_requests, stream_requests_mix, LengthDist, Pattern};

fn setup() -> (Allocation, Cluster) {
    let spec = ModelSpec::llama2_13b();
    let cluster = Cluster::env_e1();
    let opts = PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };
    (plan(&spec, &cluster, &opts).unwrap().allocation, cluster)
}

fn exec_off() -> ExecOptions {
    ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    }
}

#[test]
fn prop_continuous_batch1_is_bit_identical_to_fifo() {
    // With one batch slot and no prefill-ahead there is nothing to
    // re-batch: the continuous driver must reduce to FIFO exactly —
    // same admission times, same step arithmetic, same bits.
    let (alloc, cluster) = setup();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = exec_off();
    let gen = pair(usize_in(1, 8), usize_in(0, 1000));
    let cfg = Config {
        cases: 10,
        seed: 0xBA7C_0001,
        max_shrink_steps: 16,
    };
    let result = check(&cfg, &gen, |&(count, salt)| {
        let pattern = if salt % 2 == 0 {
            Pattern::Sporadic
        } else {
            Pattern::Bursty
        };
        let reqs = stream_requests(pattern, salt as u64, count, 0.5, 64, 3);
        let fifo = serve_interleaved(&alloc, &cluster, &bw, 1, &opts, &Script::none(), &reqs);
        let cont = serve_interleaved_opts(
            &alloc,
            &cluster,
            &bw,
            1,
            &opts,
            &Script::none(),
            &reqs,
            &BatchingOpts::continuous(0),
        );
        if fifo.requests != cont.requests {
            return Err(format!(
                "per-request metrics diverged: {:?} vs {:?}",
                fifo.requests, cont.requests
            ));
        }
        if fifo.batches != cont.batches {
            return Err(format!("batches {} vs {}", fifo.batches, cont.batches));
        }
        for (name, a, b) in [
            ("makespan", fifo.makespan, cont.makespan),
            ("decode_time", fifo.decode_time, cont.decode_time),
        ] {
            if a.to_bits() != b.to_bits() {
                return Err(format!("{name} diverged: {a} vs {b}"));
            }
        }
        if fifo.step_times != cont.step_times {
            return Err("step_times diverged".to_string());
        }
        if cont.kv_pages_allocated != 0 || cont.kv_fragmentation != 0.0 {
            return Err("pageless continuous run reported page counters".to_string());
        }
        Ok(())
    });
    assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
}

#[test]
fn prop_continuous_batch1_equals_fifo_on_mixed_length_streams() {
    // The batch-size-1 pin must survive the workload-mix axis: with one
    // slot there is still nothing to re-batch even when every request
    // carries its own (prompt_len, steps), so the continuous driver must
    // stay bit-identical to FIFO on ragged streams too.
    let (alloc, cluster) = setup();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = exec_off();
    let dist = LengthDist::Bimodal {
        short: (32, 2),
        long: (128, 6),
        long_frac: 0.5,
    };
    let gen = pair(usize_in(2, 8), usize_in(0, 1000));
    let cfg = Config {
        cases: 8,
        seed: 0xBA7C_0003,
        max_shrink_steps: 16,
    };
    let result = check(&cfg, &gen, |&(count, salt)| {
        let pattern = if salt % 2 == 0 {
            Pattern::Sporadic
        } else {
            Pattern::Bursty
        };
        let reqs = stream_requests_mix(pattern, salt as u64, count, 0.5, &dist);
        let fifo = serve_interleaved(&alloc, &cluster, &bw, 1, &opts, &Script::none(), &reqs);
        let cont = serve_interleaved_opts(
            &alloc,
            &cluster,
            &bw,
            1,
            &opts,
            &Script::none(),
            &reqs,
            &BatchingOpts::continuous(0),
        );
        if fifo.requests != cont.requests {
            return Err(format!(
                "per-request metrics diverged on a mixed stream: {:?} vs {:?}",
                fifo.requests, cont.requests
            ));
        }
        if fifo.step_times != cont.step_times
            || fifo.makespan.to_bits() != cont.makespan.to_bits()
        {
            return Err("stream timings diverged on a mixed stream".to_string());
        }
        if fifo.tokens_generated != reqs.iter().map(|r| r.steps).sum::<usize>() {
            return Err("tokens_generated must sum per-request steps".to_string());
        }
        Ok(())
    });
    assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
}

#[test]
fn bursty_continuous_strictly_improves_mean_queueing() {
    // The headline acceptance shape: 6 simultaneous requests, 2 batch
    // slots. FIFO admits {0,1} at t=0 and makes {2,3} wait one full epoch
    // and {4,5} two; continuous prefills request 2 while epoch 1 decodes
    // and back-fills slots at step boundaries, so later requests leave
    // the queue roughly one decode step apart instead of one epoch apart.
    let (alloc, cluster) = setup();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = exec_off();
    let reqs = stream_requests(Pattern::Bursty, 7, 6, 0.5, 64, 4);
    let fifo = serve_interleaved(&alloc, &cluster, &bw, 2, &opts, &Script::none(), &reqs);
    let cont = serve_interleaved_opts(
        &alloc,
        &cluster,
        &bw,
        2,
        &opts,
        &Script::none(),
        &reqs,
        &BatchingOpts::continuous(1),
    );
    assert_eq!(cont.requests.len(), 6);
    assert_eq!(cont.tokens_generated, fifo.tokens_generated);
    assert!(fifo.mean_queueing_delay() > 0.0, "FIFO must actually queue here");
    assert!(
        cont.mean_queueing_delay() < fifo.mean_queueing_delay(),
        "continuous {} must strictly beat FIFO {}",
        cont.mean_queueing_delay(),
        fifo.mean_queueing_delay()
    );
    // TTFT improves with it: the overlapped prefill is the first-token
    // path for every request that skipped an epoch wait.
    assert!(cont.mean_ttft() < fifo.mean_ttft());
}

#[test]
fn prop_bursty_continuous_never_queues_worse_than_fifo() {
    // The one-sided property behind the strict pin above, over random
    // bursty stream sizes: whatever the count/slot ratio, continuous
    // admission may not increase the mean queueing delay (equality is
    // legitimate when everything fits one batch).
    let (alloc, cluster) = setup();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = exec_off();
    let gen = pair(usize_in(1, 10), usize_in(0, 1000));
    let cfg = Config {
        cases: 10,
        seed: 0xBA7C_0002,
        max_shrink_steps: 16,
    };
    let result = check(&cfg, &gen, |&(count, salt)| {
        let reqs = stream_requests(Pattern::Bursty, salt as u64, count, 0.5, 64, 3);
        let fifo = serve_interleaved(&alloc, &cluster, &bw, 2, &opts, &Script::none(), &reqs);
        let cont = serve_interleaved_opts(
            &alloc,
            &cluster,
            &bw,
            2,
            &opts,
            &Script::none(),
            &reqs,
            &BatchingOpts::continuous(1),
        );
        if cont.requests.len() != reqs.len() {
            return Err(format!("served {} of {}", cont.requests.len(), reqs.len()));
        }
        let (f, c) = (fifo.mean_queueing_delay(), cont.mean_queueing_delay());
        if c > f + 1e-12 {
            return Err(format!("continuous queued worse: {c} > {f} (count {count})"));
        }
        Ok(())
    });
    assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
}

#[test]
fn kv_page_config_carries_the_eq8_byte_scales() {
    // Spilled pages are costed as SSD writes at `bytes_per_token[i] ×
    // tokens` per device — the config must carry exactly the Eq. 8 unit
    // (`resident_kv_bytes(alloc, i, 1)`), zero on layer-less devices.
    let (alloc, _cluster) = setup();
    let cfg = KvPageConfig::for_alloc(&alloc, 16, 1024);
    assert_eq!(cfg.bytes_per_token.len(), alloc.devices.len());
    for (i, &bpt) in cfg.bytes_per_token.iter().enumerate() {
        assert_eq!(bpt, resident_kv_bytes(&alloc, i, 1), "device {i}");
    }
    assert!(
        cfg.bytes_per_token.iter().sum::<u64>() > 0,
        "a planned allocation must host KV somewhere"
    );
    assert_eq!(cfg.spec.page_tokens, 16);
    assert_eq!(cfg.spec.total_pages(), 64);
}

#[test]
fn budget_starved_continuous_run_spills_and_pays_in_stream_time() {
    // Same stream, two budgets. The generous pool never spills; the
    // starved pool must spill (8 × 64-token prompts against an 80-token
    // budget) and the spill SSD writes land in the timeline, so the
    // starved makespan cannot be shorter.
    let (alloc, cluster) = setup();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = exec_off();
    let d = cluster.len();
    let reqs = stream_requests(Pattern::Bursty, 11, 2 * d, 0.5, 64, 3);
    let run = |budget: usize| {
        serve_interleaved_opts(
            &alloc,
            &cluster,
            &bw,
            d,
            &opts,
            &Script::none(),
            &reqs,
            &BatchingOpts::continuous(1)
                .with_kv_pages(KvPageConfig::for_alloc(&alloc, 16, budget)),
        )
    };
    let generous = run(d * (64 + 3) * 2 + 16);
    let starved = run(80);
    assert_eq!(generous.kv_pages_spilled, 0, "generous budget must not spill");
    assert!(generous.kv_pages_allocated > 0);
    assert!(starved.kv_pages_spilled > 0, "an 80-token budget must spill");
    assert!((0.0..=1.0).contains(&starved.kv_fragmentation));
    assert!(
        starved.makespan >= generous.makespan,
        "spill writes must not make the stream faster: {} < {}",
        starved.makespan,
        generous.makespan
    );
}
