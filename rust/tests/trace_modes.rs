//! Trace-mode and parallelism invariants (property tests over the in-repo
//! `util::prop` framework):
//!
//! * `TraceMode` is observational only — `Off`, `Aggregate` and `Full` runs
//!   of the same simulation produce bit-identical `SimResult` timing
//!   fields across seeds, clusters, patterns and executors.
//! * The offline scheduler's `#Seg` sweep is deterministic under
//!   parallelism — `plan()` returns the same allocation and cost curve for
//!   every worker-thread count.

use lime::cluster::Cluster;
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::pipeline::{run_interleaved, run_traditional, ExecOptions, SimResult, TradOptions};
use lime::plan::{plan_with_threads, PlanOptions};
use lime::sim::TraceMode;
use lime::util::bytes::mbps;
use lime::util::prop::{check, pair, usize_in, Config, PropResult};

fn popts() -> PlanOptions {
    PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    }
}

fn cluster_by_index(idx: usize) -> Cluster {
    match idx {
        0 => Cluster::env_e3(),
        1 => Cluster::lowmem_setting1(),
        _ => Cluster::lowmem_setting3(),
    }
}

/// The timing-relevant fields of a `SimResult` (everything except the
/// trace, which is exactly what the modes are allowed to change).
fn timing_fields(r: &SimResult) -> (f64, &[f64], u64, usize, usize) {
    (
        r.total_time,
        r.step_times.as_slice(),
        r.kv_tokens_transferred,
        r.online_plans_fired,
        r.emergency_steps,
    )
}

#[test]
fn prop_trace_mode_never_changes_interleaved_timing() {
    // Pre-plan each cluster once; the property then sweeps (cluster, seed,
    // micro, tokens) and compares Off/Aggregate/Full runs bitwise.
    let spec = ModelSpec::llama33_70b();
    let setups: Vec<(lime::plan::allocation::Allocation, Cluster)> = (0..3)
        .map(|idx| {
            let cluster = cluster_by_index(idx);
            let alloc = lime::plan::plan(&spec, &cluster, &popts())
                .expect("planning the test cluster")
                .allocation;
            (alloc, cluster)
        })
        .collect();

    let gen = pair(
        pair(usize_in(0, 2), usize_in(0, 1000)),
        pair(usize_in(1, 5), usize_in(4, 24)),
    );
    let cfg = Config {
        cases: 16,
        seed: 0x7_ACE,
        max_shrink_steps: 64,
    };
    let result = check(&cfg, &gen, |&((cluster_idx, seed), (micro, tokens))| {
        let (alloc, cluster) = &setups[cluster_idx];
        let bw = BandwidthTrace::fixed_mbps(100.0 + (seed % 150) as f64);
        let run = |mode: TraceMode| {
            run_interleaved(
                alloc,
                cluster,
                &bw,
                micro,
                tokens,
                &ExecOptions {
                    seed: seed as u64,
                    trace_mode: mode,
                    ..ExecOptions::default()
                },
            )
        };
        let full = run(TraceMode::Full);
        let agg = run(TraceMode::Aggregate);
        let off = run(TraceMode::Off);
        if timing_fields(&full) != timing_fields(&off) {
            return Err(format!(
                "Off differs from Full: {:?} vs {:?}",
                timing_fields(&off),
                timing_fields(&full)
            ));
        }
        if timing_fields(&full) != timing_fields(&agg) {
            return Err("Aggregate differs from Full".to_string());
        }
        // Mode contracts: Full materializes spans, the others do not; the
        // busy accumulators agree between Aggregate and Full.
        if full.trace.span_count() == 0 {
            return Err("Full trace recorded no spans".into());
        }
        if off.trace.span_count() != 0 || agg.trace.span_count() != 0 {
            return Err("non-Full trace materialized spans".into());
        }
        for dev in 0..cluster.len() {
            for kind in [
                lime::sim::SpanKind::Compute,
                lime::sim::SpanKind::Load,
                lime::sim::SpanKind::Comm,
            ] {
                let a = full.trace.busy(dev, kind);
                let b = agg.trace.busy(dev, kind);
                if (a - b).abs() > 1e-9 * a.abs().max(1.0) {
                    return Err(format!("busy({dev}, {kind:?}) {a} != {b}"));
                }
            }
        }
        // Aggregate's online uncovered-load must match Full's sweep-line
        // (the T_uncover cross-check at near-Off cost), span-free.
        let full_uncovered = full.trace.uncovered_loads();
        let agg_uncovered = agg.trace.uncovered_loads();
        if full_uncovered.len() != agg_uncovered.len() {
            return Err(format!(
                "uncovered lanes: Full {} vs Aggregate {}",
                full_uncovered.len(),
                agg_uncovered.len()
            ));
        }
        for (dev, (f, a)) in full_uncovered.iter().zip(&agg_uncovered).enumerate() {
            if (f - a).abs() > 1e-9 * f.abs().max(1.0) {
                return Err(format!(
                    "uncovered_load({dev}): Full {f} vs Aggregate {a}"
                ));
            }
        }
        Ok(())
    });
    match result {
        PropResult::Pass { .. } => {}
        PropResult::Fail {
            minimal,
            seed,
            message,
        } => panic!("trace-mode property failed (seed {seed}): {minimal:?}\n{message}"),
    }
}

#[test]
fn prop_trace_modes_agree_under_scripted_pressure() {
    // Satellite of the scenario-matrix work: when a scripted joint
    // fluctuation fires mid-run offload plans (one-time reload loads,
    // growing per-segment loads, emergency kv-spill/kv-fetch SSD traffic)
    // *and* sags the link, `TraceMode::Aggregate`'s online
    // `uncovered_load` must still match `Full`'s sweep-line, and every
    // timing field must stay bit-identical across Off/Aggregate/Full.
    use lime::adapt::{MemEvent, Script};
    use lime::pipeline::run_interleaved_scripted;
    use lime::util::bytes::gib;

    let spec = ModelSpec::llama33_70b();
    let setups: Vec<(lime::plan::allocation::Allocation, Cluster)> = (0..3)
        .map(|idx| {
            let cluster = cluster_by_index(idx);
            let alloc = lime::plan::plan(&spec, &cluster, &popts())
                .expect("planning the test cluster")
                .allocation;
            (alloc, cluster)
        })
        .collect();

    let gen = pair(
        pair(usize_in(0, 2), usize_in(0, 1000)),
        pair(pair(usize_in(1, 3), usize_in(8, 32)), pair(usize_in(1, 12), usize_in(0, 7))),
    );
    let cfg = Config {
        cases: 12,
        seed: 0xA66,
        max_shrink_steps: 64,
    };
    let result = check(
        &cfg,
        &gen,
        |&((cluster_idx, seed), ((micro, tokens), (squeeze_gib, at_step)))| {
            let (alloc, cluster) = &setups[cluster_idx];
            let device = seed % cluster.len();
            let script = Script::from_mem_events(
                "prop",
                vec![
                    MemEvent {
                        at_step,
                        device,
                        delta_bytes: -((gib(1.0) * squeeze_gib as u64) as i64),
                    },
                    MemEvent {
                        at_step: at_step + 3,
                        device,
                        delta_bytes: (gib(1.0) * (squeeze_gib / 2) as u64) as i64,
                    },
                ],
            )
            // Joint channel: sag the link to half capacity over the same
            // window, so the property also covers bandwidth events.
            .with_bandwidth_sag(0.5, at_step, at_step + 3);
            let bw = BandwidthTrace::fixed_mbps(100.0 + (seed % 150) as f64);
            let run = |mode: TraceMode| {
                run_interleaved_scripted(
                    alloc,
                    cluster,
                    &bw,
                    micro,
                    tokens,
                    &ExecOptions {
                        seed: seed as u64,
                        trace_mode: mode,
                        ..ExecOptions::default()
                    },
                    &script,
                )
            };
            let full = run(TraceMode::Full);
            let agg = run(TraceMode::Aggregate);
            let off = run(TraceMode::Off);
            if timing_fields(&full) != timing_fields(&off)
                || timing_fields(&full) != timing_fields(&agg)
            {
                return Err("TraceMode changed scripted-run timing".to_string());
            }
            // The interesting case: pressure injected extra SSD loads.
            for dev in 0..cluster.len() {
                for kind in [
                    lime::sim::SpanKind::Load,
                    lime::sim::SpanKind::Store,
                    lime::sim::SpanKind::Compute,
                ] {
                    let a = full.trace.busy(dev, kind);
                    let b = agg.trace.busy(dev, kind);
                    if (a - b).abs() > 1e-9 * a.abs().max(1.0) {
                        return Err(format!("busy({dev}, {kind:?}) {a} != {b}"));
                    }
                }
            }
            let full_uncovered = full.trace.uncovered_loads();
            let agg_uncovered = agg.trace.uncovered_loads();
            for (dev, (f, a)) in full_uncovered.iter().zip(&agg_uncovered).enumerate() {
                if (f - a).abs() > 1e-9 * f.abs().max(1.0) {
                    return Err(format!(
                        "uncovered_load({dev}) under pressure: Full {f} vs Aggregate {a}"
                    ));
                }
            }
            Ok(())
        },
    );
    match result {
        PropResult::Pass { .. } => {}
        PropResult::Fail {
            minimal,
            seed,
            message,
        } => panic!("pressure trace property failed (seed {seed}): {minimal:?}\n{message}"),
    }
}

#[test]
fn prop_trace_mode_never_changes_traditional_timing() {
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    let alloc = lime::plan::plan(&spec, &cluster, &popts())
        .expect("planning")
        .allocation;

    let gen = pair(usize_in(0, 1000), pair(usize_in(1, 4), usize_in(4, 16)));
    let cfg = Config {
        cases: 12,
        seed: 0x7_AD,
        max_shrink_steps: 64,
    };
    let result = check(&cfg, &gen, |&(seed, (micro, tokens))| {
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let run = |mode: TraceMode| {
            run_traditional(
                &alloc,
                &cluster,
                &bw,
                micro,
                tokens,
                &TradOptions {
                    seed: seed as u64,
                    trace_mode: mode,
                    ..TradOptions::default()
                },
            )
        };
        let full = run(TraceMode::Full);
        let off = run(TraceMode::Off);
        if timing_fields(&full) != timing_fields(&off) {
            return Err("traditional executor timing depends on TraceMode".into());
        }
        Ok(())
    });
    assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
}

#[test]
fn prop_plan_is_thread_count_invariant() {
    // Random (cluster, model, thread-count) draws: the parallel #Seg sweep
    // must return exactly the sequential scheduler's output.
    let gen = pair(pair(usize_in(0, 2), usize_in(0, 2)), usize_in(1, 9));
    let cfg = Config {
        cases: 10,
        seed: 0x5E65,
        max_shrink_steps: 32,
    };
    let result = check(&cfg, &gen, |&((cluster_idx, model_idx), threads)| {
        let cluster = cluster_by_index(cluster_idx);
        let spec = match model_idx {
            0 => ModelSpec::llama2_13b(),
            1 => ModelSpec::qwen3_32b(),
            _ => ModelSpec::llama33_70b(),
        };
        let o = popts();
        let seq = plan_with_threads(&spec, &cluster, &o, 1);
        let par = plan_with_threads(&spec, &cluster, &o, threads);
        match (seq, par) {
            (Err(a), Err(b)) => {
                if a == b {
                    Ok(())
                } else {
                    Err(format!("errors differ: {a:?} vs {b:?}"))
                }
            }
            (Ok(a), Ok(b)) => {
                if a.allocation != b.allocation {
                    return Err(format!(
                        "allocation differs at {threads} threads:\n{}\nvs\n{}",
                        a.allocation.describe(),
                        b.allocation.describe()
                    ));
                }
                if a.seg_curve != b.seg_curve {
                    return Err("seg_curve differs".into());
                }
                Ok(())
            }
            _ => Err("feasibility differs between thread counts".into()),
        }
    });
    assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
}

#[test]
fn full_trace_runs_are_deterministic() {
    // The acceptance determinism check: two identical Full-trace runs agree
    // bitwise on every timing field (and on the trace itself).
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting2();
    let alloc = lime::plan::plan(&spec, &cluster, &popts())
        .expect("planning")
        .allocation;
    let bw = BandwidthTrace::fixed_mbps(150.0);
    let a = run_interleaved(&alloc, &cluster, &bw, 3, 48, &ExecOptions::default());
    let b = run_interleaved(&alloc, &cluster, &bw, 3, 48, &ExecOptions::default());
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.step_times, b.step_times);
    assert_eq!(a.kv_tokens_transferred, b.kv_tokens_transferred);
    assert_eq!(a.online_plans_fired, b.online_plans_fired);
    assert_eq!(a.emergency_steps, b.emergency_steps);
    assert_eq!(a.trace.span_count(), b.trace.span_count());
    for (sa, sb) in a.trace.spans().zip(b.trace.spans()) {
        assert_eq!(sa, sb);
    }
}
