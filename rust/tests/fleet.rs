//! Fleet determinism properties: the sharded pool run must serialize the
//! `lime-fleet-v1` artifact byte-for-byte identically to the sequential
//! reference at any worker count, and the artifact must round-trip
//! through the parser and the strict validator. CI additionally runs the
//! `lime fleet` CLI under `LIME_THREADS={1,4}` and byte-diffs the two
//! artifact trees.

use lime::serve::fleet::{
    fleet_artifact_bytes, run_fleet_on, run_fleet_sequential, validate_fleet, FleetSpec,
    RouterPolicy,
};
use lime::util::json::Json;
use lime::util::pool::Pool;
use lime::workload::Pattern;

/// The demo fleet at integration-test scale: all four E3 subsets, every
/// router and both patterns, but a short stream.
fn small_demo() -> FleetSpec {
    FleetSpec::demo(120, 2)
}

#[test]
fn fleet_artifact_is_byte_identical_across_worker_counts() {
    let spec = small_demo();
    let reference = fleet_artifact_bytes(&spec, &run_fleet_sequential(&spec));
    for workers in [1usize, 4] {
        let pool = Pool::new(workers);
        let bytes = fleet_artifact_bytes(&spec, &run_fleet_on(&spec, Some(&pool)));
        assert_eq!(
            bytes, reference,
            "fleet artifact differs at {workers} workers"
        );
    }
}

#[test]
fn demo_artifact_validates_and_round_trips() {
    let spec = small_demo();
    let cells = run_fleet_sequential(&spec);
    let bytes = fleet_artifact_bytes(&spec, &cells);
    let parsed = Json::parse(std::str::from_utf8(&bytes).unwrap()).expect("valid JSON");
    let summary = validate_fleet(&parsed).expect("artifact validates");
    assert_eq!(summary.schema, "lime-fleet-v1");
    assert_eq!(summary.name, "e3-demo-fleet");
    assert_eq!(summary.model, "Qwen3-32B");
    assert_eq!(summary.clusters, 4);
    assert_eq!(summary.cells, 6);
    assert_eq!(summary.requests, 120);

    // Every cell serves the whole stream; routing never drops requests.
    for cell in &cells {
        assert_eq!(cell.count, 120);
        let shard_sum: usize = cell.shards.iter().map(|s| s.count).sum();
        assert_eq!(shard_sum, 120);
        assert!(cell.makespan > 0.0);
        assert!(cell.ttft.mean > 0.0);
        assert!(cell.ttft.p50 <= cell.ttft.p95 && cell.ttft.p95 <= cell.ttft.p99);
    }
}

#[test]
fn churned_fleet_artifact_is_deterministic_and_validates() {
    // Mid-stream cluster churn (down at arrival 10, back up at 60): the
    // re-routed artifact must stay byte-identical across worker counts
    // and pass the strict validator, churn header and per-cell re-route
    // counts included.
    let mut spec = small_demo();
    spec.churn = lime::adapt::Script::device_down_up("c1-blip", 1, 10, 60);
    let reference = fleet_artifact_bytes(&spec, &run_fleet_sequential(&spec));
    for workers in [1usize, 4] {
        let pool = Pool::new(workers);
        let bytes = fleet_artifact_bytes(&spec, &run_fleet_on(&spec, Some(&pool)));
        assert_eq!(
            bytes, reference,
            "churned fleet artifact differs at {workers} workers"
        );
    }
    let parsed = Json::parse(std::str::from_utf8(&reference).unwrap()).unwrap();
    let summary = validate_fleet(&parsed).expect("churned artifact validates");
    assert_eq!(summary.cells, 6);
    assert!(parsed.get("churn").is_some(), "churn header must be emitted");
    for cell in parsed.get("cells").unwrap().as_arr().unwrap() {
        assert!(cell.get("rerouted").unwrap().as_u64().is_some());
    }
}

#[test]
fn sparse_fleet_reports_zero_stats_on_idle_clusters() {
    // Two round-robin requests across four clusters: half the shards are
    // empty and must serialize as validator-clean zero stats, never NaN.
    let mut spec = small_demo();
    spec.count = 2;
    spec.routers = vec![RouterPolicy::RoundRobin];
    spec.patterns = vec![Pattern::Sporadic];
    let cells = run_fleet_sequential(&spec);
    assert_eq!(cells.len(), 1);
    let cell = &cells[0];
    assert_eq!(cell.count, 2);
    let served: Vec<usize> = cell.shards.iter().map(|s| s.count).collect();
    assert_eq!(served, vec![1, 1, 0, 0]);
    for shard in &cell.shards[2..] {
        assert_eq!(shard.makespan, 0.0);
        assert_eq!(shard.ttft.sum, 0.0);
        assert_eq!(shard.ttft.p99, 0.0);
    }
    let bytes = fleet_artifact_bytes(&spec, &cells);
    assert!(
        !std::str::from_utf8(&bytes).unwrap().contains("NaN"),
        "artifact must never contain NaN"
    );
    let parsed = Json::parse(std::str::from_utf8(&bytes).unwrap()).unwrap();
    validate_fleet(&parsed).expect("sparse artifact validates");
}
