//! Dedicated online-adaptation tests (paper §IV-D): `OnlinePlanner`
//! threshold crossings, scripted memory-pressure events through
//! `apply_pressure`, the KV-transfer protocol's reaction to
//! pressure-shifted thresholds, and the executor-level invariants of
//! `run_interleaved_scripted` (an empty joint script is bit-identical to
//! the unscripted executor; a given script is deterministic; correlated
//! multi-device dips fire plans on every affected device; bandwidth sags
//! inflate the comm terms exactly as a pre-scaled trace would).

use lime::adapt::{eq8_tokens, KvTransferProtocol, MemEvent, MemScenario, OnlinePlanner, Script};
use lime::cluster::Cluster;
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::pipeline::{run_interleaved, run_interleaved_scripted, ExecOptions, SimResult};
use lime::plan::{plan, Allocation, PlanOptions};
use lime::sim::TraceMode;
use lime::util::bytes::{gib, mbps};
use lime::util::prop::{check, pair, usize_in, Config, PropResult};

fn popts() -> PlanOptions {
    PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    }
}

fn lowmem_setup(idx: usize) -> (Allocation, Cluster) {
    let spec = ModelSpec::llama33_70b();
    let cluster = match idx {
        1 => Cluster::lowmem_setting1(),
        2 => Cluster::lowmem_setting2(),
        _ => Cluster::lowmem_setting3(),
    };
    let alloc = plan(&spec, &cluster, &popts()).expect("planning").allocation;
    (alloc, cluster)
}

fn timing_fields(r: &SimResult) -> (f64, &[f64], u64, usize, usize) {
    (
        r.total_time,
        r.step_times.as_slice(),
        r.kv_tokens_transferred,
        r.online_plans_fired,
        r.emergency_steps,
    )
}

/// A device with a finite, positive next threshold (KV pressure bites).
fn pressured_device(planner: &OnlinePlanner) -> usize {
    (0..planner.states.len())
        .filter(|&i| planner.states[i].next_threshold < usize::MAX)
        .min_by_key(|&i| planner.states[i].next_threshold)
        .expect("a lowmem setting must pressure some device")
}

// ------------------------------------------------ threshold crossings

#[test]
fn on_token_boundary_is_exact() {
    // `TS_i^j` is inclusive: one token below never fires, the threshold
    // itself is when the planner reacts (Eq. 5).
    let (alloc, cluster) = lowmem_setup(1);
    let mut planner = OnlinePlanner::new(&alloc, &cluster, 1);
    let i = pressured_device(&planner);
    let ts = planner.states[i].next_threshold;
    assert!(planner.on_token(i, ts.saturating_sub(1), 0).is_none());
    if let Some(fired) = planner.on_token(i, ts, 0) {
        assert_eq!(fired.at_tokens, ts, "plan records its trigger point");
        assert!(fired.alpha + fired.beta > 0);
    }
}

#[test]
fn crossing_the_first_threshold_fires_a_plan() {
    // Every device that is both pressured (finite TS) and has evictable
    // blocks must fire a plan when its threshold is crossed — the deficit
    // at TS^1 is a lookahead's worth of KV, far below one freed block.
    let (alloc, cluster) = lowmem_setup(1);
    let mut planner = OnlinePlanner::new(&alloc, &cluster, 1);
    let candidates: Vec<usize> = (0..planner.states.len())
        .filter(|&i| {
            planner.states[i].next_threshold < usize::MAX
                && planner.states[i].alpha_avail + planner.states[i].beta_avail > 0
        })
        .collect();
    assert!(
        !candidates.is_empty(),
        "lowmem1 must leave some device pressured with evictable blocks"
    );
    for i in candidates {
        let ts = planner.states[i].next_threshold;
        let before = planner.states[i].history.len();
        for tokens in ts..ts + 4 {
            planner.on_token(i, tokens, 0);
        }
        assert!(
            planner.states[i].history.len() > before,
            "device {i}: crossing TS={ts} fired nothing"
        );
        let last = *planner.states[i].history.last().unwrap();
        assert!(last.alpha + last.beta > 0);
        assert!(last.at_tokens >= ts);
        assert!(
            planner.next_threshold(i) > ts || planner.next_threshold(i) == usize::MAX,
            "a fired plan must push the next threshold out"
        );
    }
}

// ------------------------------------------- scripted pressure events

#[test]
fn apply_pressure_moves_thresholds_both_ways() {
    let (alloc, cluster) = lowmem_setup(1);
    let mut planner = OnlinePlanner::new(&alloc, &cluster, 1);
    let i = pressured_device(&planner);
    let t0 = planner.states[i].next_threshold;
    assert!(t0 > 0);

    // Crushing pressure: slack saturates at zero, the threshold collapses
    // to (at most) just past the current plan's trigger point.
    planner.apply_pressure(i, -(gib(128.0) as i64));
    let t1 = planner.states[i].next_threshold;
    assert!(t1 <= t0);
    assert!(t1 <= 1, "zero slack leaves only the +1 clamp, got {t1}");

    // Restoring more than was taken pushes the threshold past its start.
    planner.apply_pressure(i, gib(256.0) as i64);
    let t2 = planner.states[i].next_threshold;
    assert!(t2 >= t0, "restored slack must re-raise the threshold: {t2} < {t0}");
}

#[test]
fn dip_restores_slack_exactly_even_after_saturation() {
    // A dip whose squeeze exceeds the available slack must still be a
    // no-op once released: pressure accumulates against the unpressured
    // base, only effective slack clamps at zero.
    let (alloc, cluster) = lowmem_setup(1);
    let mut planner = OnlinePlanner::new(&alloc, &cluster, 1);
    let i = pressured_device(&planner);
    let slack0 = planner.states[i].slack_bytes;
    let ts0 = planner.states[i].next_threshold;
    let squeeze = gib(64.0) as i64; // far beyond any lowmem device's slack
    planner.apply_pressure(i, -squeeze);
    assert_eq!(planner.states[i].slack_bytes, 0, "squeeze must saturate");
    planner.apply_pressure(i, squeeze);
    assert_eq!(
        planner.states[i].slack_bytes, slack0,
        "down+up of equal magnitude must restore slack exactly"
    );
    assert_eq!(planner.states[i].next_threshold, ts0);
}

#[test]
fn pressure_triggers_adaptation_the_unpressured_run_never_needed() {
    // An 8 GiB squeeze on device 0 (under lowmem planning, slack is far
    // smaller than that) must engage §IV-D machinery: online plans, or —
    // once nothing more can be freed — the emergency KV spill.
    let (alloc, cluster) = lowmem_setup(1);
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let script = Script::from_mem_events(
        "squeeze",
        vec![MemEvent {
            at_step: 4,
            device: 0,
            delta_bytes: -(gib(8.0) as i64),
        }],
    );
    let squeezed = run_interleaved_scripted(&alloc, &cluster, &bw, 1, 48, &opts, &script);
    assert!(
        squeezed.online_plans_fired > 0 || squeezed.emergency_steps > 0,
        "8 GiB of pressure engaged nothing: {squeezed:?}"
    );
    // And the pressure must actually cost something relative to baseline.
    let baseline = run_interleaved(&alloc, &cluster, &bw, 1, 48, &opts);
    assert!(
        squeezed.total_time >= baseline.total_time,
        "pressure cannot make the run faster: {} < {}",
        squeezed.total_time,
        baseline.total_time
    );
}

#[test]
fn prop_empty_joint_script_is_bit_identical_to_unscripted() {
    let setups: Vec<(Allocation, Cluster)> = (1..=3).map(lowmem_setup).collect();
    let gen = pair(
        pair(usize_in(0, 2), usize_in(0, 1000)),
        pair(usize_in(1, 5), usize_in(4, 24)),
    );
    let cfg = Config {
        cases: 12,
        seed: 0xADA7,
        max_shrink_steps: 64,
    };
    let result = check(&cfg, &gen, |&((ci, seed), (micro, tokens))| {
        let (alloc, cluster) = &setups[ci];
        let bw = BandwidthTrace::fixed_mbps(100.0 + (seed % 150) as f64);
        let opts = ExecOptions {
            seed: seed as u64,
            trace_mode: TraceMode::Off,
            ..ExecOptions::default()
        };
        let plain = run_interleaved(alloc, cluster, &bw, micro, tokens, &opts);
        let scripted =
            run_interleaved_scripted(alloc, cluster, &bw, micro, tokens, &opts, &Script::none());
        if timing_fields(&plain) != timing_fields(&scripted)
            || plain.bw_stalls != scripted.bw_stalls
        {
            return Err(format!(
                "empty joint script diverged: {:?} vs {:?}",
                timing_fields(&scripted),
                timing_fields(&plain)
            ));
        }
        Ok(())
    });
    assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
}

#[test]
fn prop_scripted_runs_are_deterministic() {
    let (alloc, cluster) = lowmem_setup(2);
    let gen = pair(
        pair(usize_in(0, 4), usize_in(1, 16)),
        pair(usize_in(1, 12), usize_in(8, 24)),
    );
    let cfg = Config {
        cases: 10,
        seed: 0xDE7,
        max_shrink_steps: 32,
    };
    let result = check(&cfg, &gen, |&((device, squeeze_gib), (at_step, tokens))| {
        let bw = BandwidthTrace::fixed_mbps(150.0);
        let opts = ExecOptions {
            trace_mode: TraceMode::Off,
            ..ExecOptions::default()
        };
        let script = Script::from_mem_events(
            "det",
            vec![
                MemEvent {
                    at_step,
                    device,
                    delta_bytes: -((gib(1.0) * squeeze_gib as u64) as i64),
                },
                MemEvent {
                    at_step: at_step + 4,
                    device,
                    delta_bytes: (gib(1.0) * squeeze_gib as u64) as i64,
                },
            ],
        )
        .with_bandwidth_sag(0.5, at_step, at_step + 4);
        let a = run_interleaved_scripted(&alloc, &cluster, &bw, 2, tokens, &opts, &script);
        let b = run_interleaved_scripted(&alloc, &cluster, &bw, 2, tokens, &opts, &script);
        if timing_fields(&a) != timing_fields(&b) || a.bw_stalls != b.bw_stalls {
            return Err("same script, different outcome".into());
        }
        Ok(())
    });
    assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
}

// ------------------------------------- KV transfer under pressure

#[test]
fn pressure_makes_lazy_bandwidth_increase_imminent() {
    // Alg. 2 line 15: a bandwidth *increase* is normally skipped far from
    // the next threshold. Scripted pressure collapses the threshold, which
    // must flip the same increase to "imminent" and update the shipper.
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting2();
    let opts = PlanOptions {
        empirical_tokens: 256,
        micro_batch: 1,
        bandwidth: mbps(100.0),
    };
    let alloc = plan(&spec, &cluster, &opts).expect("planning").allocation;
    let mut planner = OnlinePlanner::new(&alloc, &cluster, 1);
    let mut proto = KvTransferProtocol::new(&alloc, &cluster, &planner, 256, 1, mbps(100.0));
    let Some(i) = (0..proto.states.len()).find(|&i| proto.states[i].desired > 0) else {
        return; // plan fully covered at this operating point: nothing to test
    };
    let before = proto.states[i].desired;
    let fresh = eq8_tokens(&alloc, &cluster, i, 256, 1, mbps(250.0));
    if (fresh - before).abs() < proto.n_ts {
        return; // bandwidth delta inside hysteresis: nothing observable
    }

    // Far from the threshold the increase is skipped...
    let changed = proto.on_bandwidth(&alloc, &cluster, &planner, 0, 256, 1, mbps(250.0));
    assert!(!changed.contains(&i), "increase must be lazy far from TS");
    assert_eq!(proto.states[i].desired, before);

    // ...but after crushing pressure the next threshold is imminent, so
    // the same increase is applied. (Drop back to 100 first so the retry
    // is again an increase.)
    proto.on_bandwidth(&alloc, &cluster, &planner, 0, 256, 1, mbps(100.0));
    planner.apply_pressure(i, -(gib(128.0) as i64));
    assert!(planner.next_threshold(i) <= 1);
    let changed = proto.on_bandwidth(&alloc, &cluster, &planner, 0, 256, 1, mbps(250.0));
    assert!(
        changed.contains(&i),
        "pressure-collapsed threshold must make the increase imminent"
    );
}

#[test]
fn executor_ships_kv_under_imminent_pressure() {
    // End to end: with transfer enabled, a squeezed run must not ship
    // *less* KV than the unsqueezed run — lowered thresholds only widen
    // the imminence window that gates shipping.
    let (alloc, cluster) = lowmem_setup(2);
    let bw = BandwidthTrace::fixed_mbps(100.0);
    let opts = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let script = Script::from_mem_events(
        "squeeze",
        vec![MemEvent {
            at_step: 2,
            device: 1,
            delta_bytes: -(gib(8.0) as i64),
        }],
    );
    let base = run_interleaved(&alloc, &cluster, &bw, 1, 64, &opts);
    let squeezed = run_interleaved_scripted(&alloc, &cluster, &bw, 1, 64, &opts, &script);
    assert!(
        squeezed.kv_tokens_transferred >= base.kv_tokens_transferred,
        "squeeze narrowed shipping: {} < {}",
        squeezed.kv_tokens_transferred,
        base.kv_tokens_transferred
    );
}

// -------------------------- correlated multi-device pressure scripts

#[test]
fn correlated_dip_fires_plans_on_all_affected_devices() {
    // A correlated crushing dip over several devices must collapse every
    // affected device's threshold, and the very next on_token must fire a
    // plan on each one that still has evictable blocks — neighbours react
    // together, not just the first device hit.
    let (alloc, cluster) = lowmem_setup(1);
    let mut planner = OnlinePlanner::new(&alloc, &cluster, 1);
    let devices: Vec<usize> = (0..cluster.len().min(3)).collect();
    let script = MemScenario::correlated_dip("corr", &devices, 1, gib(64.0), 2, 40);
    // Replay the down events exactly as the executor would.
    for ev in script.events.iter().filter(|e| e.delta_bytes < 0) {
        planner.apply_pressure(ev.device, ev.delta_bytes);
    }
    for &i in &devices {
        assert!(
            planner.next_threshold(i) <= 1,
            "device {i}: crushing correlated dip must collapse the threshold, got {}",
            planner.next_threshold(i)
        );
        let st = &planner.states[i];
        let evictable = st.alpha_avail + st.beta_avail > 0;
        let before = st.history.len();
        planner.on_token(i, 2, 0);
        if evictable {
            assert!(
                planner.states[i].history.len() > before,
                "device {i}: collapsed threshold fired no plan"
            );
        }
    }
    // Executor-level: the same correlated dip engages adaptation.
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let dip = MemScenario::correlated_dip("corr", &devices, 1, gib(8.0), 4, 40);
    let corr = Script::from_mem(dip);
    let run = run_interleaved_scripted(&alloc, &cluster, &bw, 1, 48, &opts, &corr);
    assert!(
        run.online_plans_fired > 0 || run.emergency_steps > 0,
        "correlated pressure engaged nothing: {run:?}"
    );
}

#[test]
fn staggered_squeeze_lags_the_later_devices() {
    // The planner of a later-staggered device must stay unpressured until
    // its own event step: replaying the script prefix up to step k only
    // collapses devices whose events have fired.
    let (alloc, cluster) = lowmem_setup(1);
    let devices = [0usize, 1];
    let script = MemScenario::staggered_squeeze("stagger", &devices, 5, gib(64.0), 2);
    let mut planner = OnlinePlanner::new(&alloc, &cluster, 1);
    let t1_before = planner.next_threshold(1);
    // Apply only the events at steps < 7 (device 0 fires at 2, device 1 at 7).
    for ev in script.events.iter().filter(|e| e.at_step < 7) {
        planner.apply_pressure(ev.device, ev.delta_bytes);
    }
    assert!(planner.next_threshold(0) <= 1, "device 0 squeezed");
    assert_eq!(
        planner.next_threshold(1),
        t1_before,
        "device 1 must be untouched before its stagger step"
    );
}

// ----------------------------------- bandwidth channel (joint scripts)

#[test]
fn bandwidth_sag_matches_prescaled_trace_exactly() {
    // Comm-term exactness: a scripted sag over a fixed base trace must be
    // bit-identical to running the unscripted executor on the manually
    // pre-scaled piecewise trace — the sag enters Eq. 2's comm terms (and
    // Alg. 2's monitor) through the exact same numbers.
    let (alloc, cluster) = lowmem_setup(1);
    let base_mbps = 200.0;
    let (from, to) = (4usize, 12usize);
    let scale = 0.5;
    let opts = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let base = BandwidthTrace::fixed_mbps(base_mbps);
    let sag = Script::bandwidth_sag("sag", scale, from, to);
    let scripted = run_interleaved_scripted(&alloc, &cluster, &base, 1, 24, &opts, &sag);
    let manual_trace = BandwidthTrace::Piecewise(vec![
        (0, mbps(base_mbps)),
        (from, mbps(base_mbps) * scale),
        (to, mbps(base_mbps)),
    ]);
    let manual = run_interleaved(&alloc, &cluster, &manual_trace, 1, 24, &opts);
    assert_eq!(timing_fields(&scripted), timing_fields(&manual));
    assert_eq!(scripted.bw_stalls, manual.bw_stalls);
    // And the sag must cost something relative to the unsagged run.
    let unsagged = run_interleaved(&alloc, &cluster, &base, 1, 24, &opts);
    assert!(
        scripted.total_time >= unsagged.total_time,
        "halving the link cannot speed the run up: {} < {}",
        scripted.total_time,
        unsagged.total_time
    );
}

#[test]
fn joint_script_engages_both_channels_in_one_run() {
    let (alloc, cluster) = lowmem_setup(1);
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let joint = Script::from_mem(MemScenario::squeeze("sq", 0, gib(8.0), 4))
        .with_bandwidth_sag(0.25, 4, 20)
        .with_label("joint");
    let baseline = run_interleaved(&alloc, &cluster, &bw, 1, 32, &opts);
    let run = run_interleaved_scripted(&alloc, &cluster, &bw, 1, 32, &opts, &joint);
    assert!(
        run.online_plans_fired > 0 || run.emergency_steps > 0,
        "memory channel engaged nothing: {run:?}"
    );
    assert!(
        run.total_time >= baseline.total_time,
        "joint pressure cannot make the run faster: {} < {}",
        run.total_time,
        baseline.total_time
    );
}
