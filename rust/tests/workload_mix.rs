//! Differential/property layer pinning the mixed-length workload axis
//! (see `docs/SERVING.md` and `docs/SWEEPS.md`):
//!
//! * **Fixed ≡ pre-mix**: `LengthDist::Fixed` streams are bit-identical
//!   to the global-knob path end-to-end — the generator reproduces
//!   `stream_requests` exactly, and serving a `Fixed(P, S)` stream under
//!   the default `ExecOptions` equals serving it with `prompt_tokens = P`
//!   as the global knob, on the FIFO *and* the continuous driver (the
//!   per-request install path replays the pre-mix arithmetic bit for
//!   bit). A matrix without `with_workloads` serializes byte-identically
//!   to one carrying the explicit singleton `Fixed` axis, and the v7
//!   artifact downgrades to v6 by schema relabel alone.
//! * **Determinism**: mixed-length matrices are bit-identical between
//!   the pooled and sequential evaluations and across re-runs — this
//!   suite rides CI's LIME_THREADS={1,4} matrix, so nothing here may
//!   depend on worker count.
//! * **Batching under a mix**: on a bursty bimodal stream, step-level
//!   continuous batching strictly improves the mean queueing delay over
//!   FIFO (short requests free slots early; FIFO holds them hostage to
//!   the batch's longest request).
//! * **Per-request lengths honored**: heterogeneous step counts produce
//!   per-request finish times and per-request TBT denominators; the
//!   paged KV allocator conserves pages under fuzzed variable-length
//!   register/append/release churn with mid-stream eviction.

use lime::adapt::Script;
use lime::cluster::Cluster;
use lime::experiments::{validate_sweep, validate_sweep_v7, ArrivalSpec, ScenarioMatrix};
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::pipeline::{run_interleaved, ExecOptions};
use lime::plan::{plan, Allocation, PlanOptions};
use lime::serve::{serve_interleaved, serve_interleaved_opts, BatchingOpts, KvPagePool, KvPageSpec};
use lime::sim::TraceMode;
use lime::util::bytes::mbps;
use lime::util::json::Json;
use lime::util::prop::{check, pair, usize_in, Config, PropResult};
use lime::util::rng::Rng;
use lime::workload::{stream_requests, stream_requests_mix, LengthDist, Pattern, Request};

fn setup() -> (Allocation, Cluster) {
    let spec = ModelSpec::llama2_13b();
    let cluster = Cluster::env_e1();
    let opts = PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };
    (plan(&spec, &cluster, &opts).unwrap().allocation, cluster)
}

fn exec_off() -> ExecOptions {
    ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    }
}

/// Bitwise stream-result comparison (shared by the differential props).
fn diff_streams(a: &lime::serve::StreamResult, b: &lime::serve::StreamResult) -> Result<(), String> {
    if a.requests != b.requests {
        return Err(format!(
            "per-request metrics diverged: {:?} vs {:?}",
            a.requests, b.requests
        ));
    }
    if a.batches != b.batches {
        return Err(format!("batches {} vs {}", a.batches, b.batches));
    }
    if a.tokens_generated != b.tokens_generated {
        return Err("tokens_generated diverged".into());
    }
    for (name, x, y) in [
        ("makespan", a.makespan, b.makespan),
        ("decode_time", a.decode_time, b.decode_time),
    ] {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{name} diverged: {x} vs {y}"));
        }
    }
    if a.step_times != b.step_times {
        return Err("step_times diverged".into());
    }
    Ok(())
}

#[test]
fn prop_fixed_dist_serving_is_bit_identical_to_the_global_knob_path() {
    // Knob-independence half of the backward-compatibility pin: a
    // `Fixed(P, S)` stream served under the *default* options (global
    // knob still 64) is bit-identical to the same stream served with
    // `prompt_tokens = P` — once per-request lengths are installed, the
    // knob is inert, on both drivers. The companion test below anchors
    // the installed path to `run_interleaved` (no slot lengths at all),
    // which together make serving `Fixed(P, S)` ≡ the pre-mix
    // global-knob arithmetic at P.
    let (alloc, cluster) = setup();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let prompts = [16usize, 32, 64, 96];
    let gen = pair(pair(usize_in(1, 6), usize_in(0, 3)), pair(usize_in(1, 5), usize_in(0, 500)));
    let cfg = Config {
        cases: 12,
        seed: 0x3117_0001,
        max_shrink_steps: 16,
    };
    let result = check(&cfg, &gen, |&((count, pi), (steps, salt))| {
        let p = prompts[pi];
        let pattern = if salt % 2 == 0 {
            Pattern::Sporadic
        } else {
            Pattern::Bursty
        };
        let dist = LengthDist::fixed(p, steps);
        let reqs = stream_requests_mix(pattern, salt as u64, count, 0.5, &dist);
        // Generator identity: Fixed draws nothing from the RNG, so the
        // mix generator IS the pre-mix generator.
        if reqs != stream_requests(pattern, salt as u64, count, 0.5, p, steps) {
            return Err(format!("generator diverged for P={p} S={steps}"));
        }
        let knob_default = exec_off(); // prompt_tokens = 64, whatever P is
        let knob_p = ExecOptions {
            prompt_tokens: p,
            ..exec_off()
        };
        for max_batch in [1usize, 2] {
            let a = serve_interleaved(&alloc, &cluster, &bw, max_batch, &knob_default, &Script::none(), &reqs);
            let b = serve_interleaved(&alloc, &cluster, &bw, max_batch, &knob_p, &Script::none(), &reqs);
            diff_streams(&a, &b).map_err(|e| format!("fifo mb={max_batch} P={p}: {e}"))?;
            let ca = serve_interleaved_opts(
                &alloc,
                &cluster,
                &bw,
                max_batch,
                &knob_default,
                &Script::none(),
                &reqs,
                &BatchingOpts::continuous(1),
            );
            let cb = serve_interleaved_opts(
                &alloc,
                &cluster,
                &bw,
                max_batch,
                &knob_p,
                &Script::none(),
                &reqs,
                &BatchingOpts::continuous(1),
            );
            diff_streams(&ca, &cb).map_err(|e| format!("cont mb={max_batch} P={p}: {e}"))?;
        }
        Ok(())
    });
    assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
}

#[test]
fn prop_fixed_dist_single_batch_matches_run_interleaved_at_that_prompt() {
    // The anchor half of the backward-compatibility pin: serving a
    // bursty `Fixed(P, S)` burst under the *default* knob reproduces
    // `run_interleaved` with `prompt_tokens = P` — the executor with no
    // slot lengths installed at all, i.e. the literal pre-mix
    // global-knob arithmetic, for every P (not just the default 64 that
    // `serving_stream.rs` pins).
    let (alloc, cluster) = setup();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let prompts = [16usize, 32, 64, 96];
    let gen = pair(pair(usize_in(1, 4), usize_in(0, 3)), usize_in(1, 8));
    let cfg = Config {
        cases: 12,
        seed: 0x3117_0002,
        max_shrink_steps: 16,
    };
    let result = check(&cfg, &gen, |&((micro, pi), steps)| {
        let p = prompts[pi];
        // A bursty stream admits as one batch of width `micro` at t = 0 —
        // exactly the shape `run_interleaved(micro, steps)` computes.
        let reqs =
            stream_requests_mix(Pattern::Bursty, 0xE0, micro, 1.0, &LengthDist::fixed(p, steps));
        let sr = serve_interleaved(&alloc, &cluster, &bw, micro, &exec_off(), &Script::none(), &reqs);
        let knob = ExecOptions {
            prompt_tokens: p,
            ..exec_off()
        };
        let direct = run_interleaved(&alloc, &cluster, &bw, micro, steps, &knob);
        if sr.step_times != direct.step_times {
            return Err(format!(
                "P={p} micro={micro} steps={steps}: stream {:?} != direct {:?}",
                sr.step_times, direct.step_times
            ));
        }
        if sr.kv_tokens_transferred != direct.kv_tokens_transferred
            || sr.online_plans_fired != direct.online_plans_fired
            || sr.emergency_steps != direct.emergency_steps
            || sr.bw_stalls != direct.bw_stalls
        {
            return Err(format!("P={p} micro={micro} steps={steps}: counters diverged"));
        }
        Ok(())
    });
    assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
}

#[test]
fn empty_prompts_fall_back_to_the_global_knob() {
    // `serve::fleet` streams zero-token prompts (memory-flat at 10^6
    // requests) and relies on `prompt_tokens` for prefill; pin that an
    // empty-prompt stream is bit-identical to the same stream with
    // materialized knob-length prompts, on both drivers — i.e. the
    // per-request install path treats an empty prompt as "use the knob"
    // for prefill, KV growth and page registration alike.
    let (alloc, cluster) = setup();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = exec_off(); // prompt_tokens = 64
    for pattern in [Pattern::Sporadic, Pattern::Bursty] {
        let full = stream_requests(pattern, 0xF1EE7, 6, 1.0, 64, 4);
        let mut empty = full.clone();
        for r in &mut empty {
            r.prompt.clear();
        }
        for max_batch in [1usize, 3] {
            let a =
                serve_interleaved(&alloc, &cluster, &bw, max_batch, &opts, &Script::none(), &full);
            let b =
                serve_interleaved(&alloc, &cluster, &bw, max_batch, &opts, &Script::none(), &empty);
            diff_streams(&a, &b).unwrap_or_else(|e| panic!("fifo {pattern:?} mb={max_batch}: {e}"));
            let ca = serve_interleaved_opts(
                &alloc,
                &cluster,
                &bw,
                max_batch,
                &opts,
                &Script::none(),
                &full,
                &BatchingOpts::continuous(1),
            );
            let cb = serve_interleaved_opts(
                &alloc,
                &cluster,
                &bw,
                max_batch,
                &opts,
                &Script::none(),
                &empty,
                &BatchingOpts::continuous(1),
            );
            diff_streams(&ca, &cb)
                .unwrap_or_else(|e| panic!("cont {pattern:?} mb={max_batch}: {e}"));
        }
    }
}

/// A small stream-bearing matrix over the env-E1 cluster; `workloads`
/// empty = the constructor's implicit fixed axis.
fn small_matrix<'a>(
    methods: &'a [Box<dyn lime::baselines::Method>],
    workloads: Vec<LengthDist>,
) -> ScenarioMatrix<'a> {
    let m = ScenarioMatrix::new(
        "mix-test",
        ModelSpec::llama2_13b(),
        Cluster::env_e1(),
        methods,
        vec![100.0, 200.0],
        vec![Pattern::Sporadic, Pattern::Bursty],
        3,
    )
    .with_arrivals(vec![
        ArrivalSpec::Single,
        ArrivalSpec::Stream {
            count: 4,
            lambda: 1.0,
        },
    ]);
    if workloads.is_empty() {
        m
    } else {
        m.with_workloads(workloads)
    }
}

#[test]
fn fixed_workload_matrix_matches_the_default_and_downgrades_to_v6() {
    // Axis-level Fixed pin: a matrix that never calls `with_workloads`
    // and one carrying the explicit singleton `Fixed(64, tokens)` axis
    // must serialize byte-identically (the constructor's default IS that
    // singleton), and the v7 artifact must downgrade to v6 by schema
    // relabel alone — v7 is a strict superset.
    let methods = lime::baselines::all();
    let implicit = small_matrix(&methods, vec![]);
    let explicit = small_matrix(&methods, vec![LengthDist::fixed(64, 3)]);
    let a = implicit.eval_sequential();
    let b = explicit.eval_sequential();
    assert_eq!(a.len(), b.len());
    let ja = implicit.to_json(&a).to_string();
    let jb = explicit.to_json(&b).to_string();
    assert_eq!(ja, jb, "explicit singleton Fixed axis must change nothing");

    let parsed = Json::parse(&ja).unwrap();
    let summary = validate_sweep_v7(&parsed).expect("v7 artifact validates");
    assert_eq!(summary.schema, "lime-sweep-v7");
    assert_eq!(summary.cells, implicit.cell_count());

    // Strict-superset downgrade: relabel the schema tag, nothing else.
    let relabelled = ja.replacen("lime-sweep-v7", "lime-sweep-v6", 1);
    assert_ne!(relabelled, ja);
    let v6 = validate_sweep(&Json::parse(&relabelled).unwrap())
        .expect("relabelled v6 artifact validates");
    assert_eq!(v6.schema, "lime-sweep-v6");
}

#[test]
fn mixed_length_matrix_is_deterministic_across_worker_counts_and_reruns() {
    // Satellite 1b: a genuinely ragged matrix must be bit-identical
    // between the pooled and the sequential evaluation and across
    // re-runs. CI runs this binary under LIME_THREADS=1 and =4 and
    // byte-diffs full sweep artifacts on top, so the pooled side really
    // executes at both worker counts.
    let methods = lime::baselines::all();
    let m = small_matrix(
        &methods,
        vec![
            LengthDist::fixed(64, 3),
            LengthDist::Bimodal {
                short: (32, 2),
                long: (128, 8),
                long_frac: 0.5,
            },
        ],
    );
    let pooled = m.eval();
    let sequential = m.eval_sequential();
    assert_eq!(pooled.len(), m.cell_count());
    assert_eq!(pooled.len(), sequential.len());
    for (p, s) in pooled.iter().zip(&sequential) {
        assert_eq!(p, s, "mixed-length cell diverged between pool and sequential");
    }
    let ja = m.to_json(&pooled).to_string();
    assert_eq!(ja, m.to_json(&sequential).to_string());
    // Seed-reproducible: evaluating again replays the identical stream.
    assert_eq!(ja, m.to_json(&m.eval()).to_string());
    // The mix really happened: some completed cell carries a ragged
    // prompt_len array on-mode with the bimodal distribution.
    assert!(
        pooled.iter().any(|c| c.requests.as_ref().is_some_and(|r| {
            r.prompt_len.contains(&32) && r.prompt_len.contains(&128)
        })),
        "no ragged stream cell evaluated"
    );
    validate_sweep_v7(&Json::parse(&ja).unwrap()).expect("mixed v7 artifact validates");
}

#[test]
fn bimodal_bursty_continuous_strictly_improves_mean_queueing() {
    // Satellite 1c. Six simultaneous bimodal requests, two batch slots:
    // FIFO holds each epoch open for its longest member (8 steps even
    // when the twin finished after 2), so later requests wait whole
    // epochs; continuous releases the short slot at its own finish and
    // back-fills between decode steps.
    let (alloc, cluster) = setup();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = exec_off();
    let dist = LengthDist::Bimodal {
        short: (32, 2),
        long: (128, 8),
        long_frac: 0.5,
    };
    let reqs = stream_requests_mix(Pattern::Bursty, 0, 6, 0.5, &dist);
    // The seed-0 draw mixes both modes with a short+long first batch.
    assert!(reqs.iter().any(|r| r.steps == 2) && reqs.iter().any(|r| r.steps == 8));
    let fifo = serve_interleaved(&alloc, &cluster, &bw, 2, &opts, &Script::none(), &reqs);
    let cont = serve_interleaved_opts(
        &alloc,
        &cluster,
        &bw,
        2,
        &opts,
        &Script::none(),
        &reqs,
        &BatchingOpts::continuous(1),
    );
    assert_eq!(cont.requests.len(), 6);
    let want_tokens: usize = reqs.iter().map(|r| r.steps).sum();
    assert_eq!(fifo.tokens_generated, want_tokens);
    assert_eq!(cont.tokens_generated, want_tokens);
    assert!(fifo.mean_queueing_delay() > 0.0, "FIFO must actually queue here");
    assert!(
        cont.mean_queueing_delay() < fifo.mean_queueing_delay(),
        "continuous {} must strictly beat FIFO {} on the bimodal burst",
        cont.mean_queueing_delay(),
        fifo.mean_queueing_delay()
    );
}

#[test]
fn heterogeneous_steps_finish_independently_and_tbt_uses_own_step_count() {
    // The satellite-2 regression: `Request::steps` is honored per
    // request, not flattened to the batch maximum. Two simultaneous
    // requests share one FIFO batch; the 2-step member must finish
    // strictly before the 8-step member, and each TBT must average over
    // the request's *own* step count.
    let (alloc, cluster) = setup();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let opts = exec_off();
    let mk = |id: u64, steps: usize| Request {
        id,
        arrival: 0.0,
        prompt: vec![7; 64],
        steps,
        session_id: id,
        cached_prefix: 0,
    };
    let reqs = vec![mk(0, 8), mk(1, 2)];
    let r = serve_interleaved(&alloc, &cluster, &bw, 2, &opts, &Script::none(), &reqs);
    assert_eq!(r.requests.len(), 2);
    assert_eq!(r.tokens_generated, 10, "Σ per-request steps, not 2 × max");
    let long = r.requests.iter().find(|m| m.id == 0).unwrap();
    let short = r.requests.iter().find(|m| m.id == 1).unwrap();
    // Shared admission: same batch, same prefill, same first token.
    assert_eq!(long.admitted_at.to_bits(), short.admitted_at.to_bits());
    assert_eq!(long.ttft.to_bits(), short.ttft.to_bits());
    // Independent completion: the short request's last token lands at
    // decode step 2, strictly before the long one's step 8.
    assert!(
        short.finish < long.finish,
        "2-step request must finish before its 8-step batchmate: {} vs {}",
        short.finish,
        long.finish
    );
    assert_eq!(long.finish, r.makespan);
    // TBT denominators are per-request: each mean × its own step count
    // recovers that request's decode span, and the short span is a
    // strict prefix of the long one.
    let span_short = short.tbt * 2.0;
    let span_long = long.tbt * 8.0;
    assert!(span_short > 0.0 && span_long > span_short);
    assert!(((short.finish - span_short) - (long.finish - span_long)).abs() < 1e-9);
}

#[test]
fn prop_paged_pool_conserves_pages_under_mixed_length_churn() {
    // Satellite 1d: fuzzed register/append/release churn with
    // variable-length contexts against a budget small enough to force
    // mid-stream eviction (spills). After every operation the page
    // accounting must balance — no leak, no double-booked page — and
    // draining the stream must return the pool to empty.
    let gen = pair(pair(usize_in(2, 6), usize_in(48, 256)), usize_in(0, 10_000));
    let cfg = Config {
        cases: 24,
        seed: 0x9A6E_0001,
        max_shrink_steps: 16,
    };
    let result = check(&cfg, &gen, |&((page_tokens, budget_tokens), salt)| {
        let spec = KvPageSpec::new(page_tokens, budget_tokens);
        let total = spec.total_pages();
        let mut pool = KvPagePool::new(spec);
        let mut rng = Rng::new(salt as u64);
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        let mut drained_tokens = 0usize;
        let balance = |pool: &KvPagePool, what: &str| -> Result<(), String> {
            if pool.pages_in_use() + pool.free_pages() != total {
                return Err(format!(
                    "{what}: {} in use + {} free != {total} total",
                    pool.pages_in_use(),
                    pool.free_pages()
                ));
            }
            let f = pool.fragmentation();
            if !(0.0..=1.0).contains(&f) {
                return Err(format!("{what}: fragmentation {f} out of [0,1]"));
            }
            Ok(())
        };
        for _ in 0..120 {
            match rng.below(4) {
                // Admit a variable-length context (ragged prompts).
                0 | 1 => {
                    let tokens = 1 + rng.below(96) as usize;
                    pool.register(next_id, tokens);
                    live.push(next_id);
                    next_id += 1;
                    balance(&pool, "register")?;
                }
                // Grow a random live context by one decode token.
                2 if !live.is_empty() => {
                    let id = live[rng.below(live.len() as u64) as usize];
                    pool.append_token(id);
                    balance(&pool, "append")?;
                }
                // Mid-stream eviction of a random live context.
                3 if !live.is_empty() => {
                    let id = live.swap_remove(rng.below(live.len() as u64) as usize);
                    pool.release(id);
                    balance(&pool, "release")?;
                }
                _ => {}
            }
            drained_tokens += pool.take_spilled_tokens();
        }
        // Spill accounting: every spilled page moved at most one page of
        // tokens, and the drain saw every one of them.
        drained_tokens += pool.take_spilled_tokens();
        if drained_tokens > pool.pages_spilled() as usize * page_tokens {
            return Err(format!(
                "drained {drained_tokens} tokens from {} spilled pages of {page_tokens}",
                pool.pages_spilled()
            ));
        }
        // Drain the stream: releasing every live context must return the
        // pool to exactly-empty — the no-leak half of the contract.
        for id in live.drain(..) {
            pool.release(id);
        }
        if pool.pages_in_use() != 0 || pool.free_pages() != total {
            return Err(format!(
                "leak: {} pages still in use, {} free of {total}",
                pool.pages_in_use(),
                pool.free_pages()
            ));
        }
        Ok(())
    });
    assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
}
