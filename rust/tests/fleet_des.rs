//! Event-driven router properties: the heap-indexed DES router must make
//! *exactly* the legacy scan's decisions whenever affinity is off (any
//! policy, any pattern, tie-heavy and degenerate plan signals included);
//! the affinity-enabled fleet must serialize its `lime-fleet-v2`
//! artifact byte-for-byte identically at any worker count; and the
//! MTBF churn generator must drive the fleet churn channel
//! deterministically. CI runs this suite on both determinism legs.

use lime::adapt::Script;
use lime::serve::fleet::{
    fleet_artifact_bytes, route, route_scan, run_fleet_on, run_fleet_sequential, schema_tag,
    validate_fleet, FleetCluster, FleetSpec, RouterPolicy,
};
use lime::util::json::Json;
use lime::util::pool::Pool;
use lime::workload::{stream_requests, stream_requests_mix, LengthDist, Pattern, Request};

/// The demo fleet's four heterogeneous clusters, plus two adversarial
/// variants of the plan signal: all-equal rates (every PlanAware key
/// collides; ties must all break low) and a NaN rate (PlanAware must
/// fall back to the JSQ criterion in both implementations).
fn cluster_tables() -> Vec<(&'static str, Vec<FleetCluster>)> {
    let base = FleetSpec::demo(1, 1).clusters;
    let mut equal = base.clone();
    for c in &mut equal {
        c.planned_s_per_token = 0.25;
    }
    let mut degenerate = base.clone();
    degenerate[2].planned_s_per_token = f64::NAN;
    vec![
        ("heterogeneous", base),
        ("tie-heavy", equal),
        ("degenerate-plan", degenerate),
    ]
}

fn assert_routes_match(label: &str, requests: &[Request], clusters: &[FleetCluster]) {
    for policy in RouterPolicy::all() {
        let des = route(policy, requests, clusters);
        let scan = route_scan(policy, requests, clusters);
        assert_eq!(
            des,
            scan,
            "DES router diverged from the scan: {label}, policy {}",
            policy.key()
        );
        let routed: usize = des.iter().map(Vec::len).sum();
        assert_eq!(routed, requests.len(), "{label}: requests dropped or duplicated");
    }
}

#[test]
fn des_router_decisions_match_the_legacy_scan_exactly() {
    for (label, clusters) in cluster_tables() {
        for pattern in [Pattern::Sporadic, Pattern::Bursty] {
            for seed in [1u64, 0xBADC_0FFE, 42] {
                let requests = stream_requests(pattern, seed, 600, 200.0, 64, 4);
                assert_routes_match(label, &requests, &clusters);
            }
        }
    }
}

#[test]
fn des_router_matches_the_scan_on_mixed_length_streams() {
    // Ragged step counts force the plan-finish heap to rebuild whenever
    // the request length changes — the mixed-length exactness path.
    let dist = LengthDist::Bimodal {
        short: (32, 2),
        long: (128, 12),
        long_frac: 0.4,
    };
    for (label, clusters) in cluster_tables() {
        for seed in [7u64, 0x51DE] {
            let requests = stream_requests_mix(Pattern::Sporadic, seed, 500, 200.0, &dist);
            assert!(
                requests.iter().any(|r| r.steps != requests[0].steps),
                "stream must actually be ragged"
            );
            assert_routes_match(label, &requests, &clusters);
        }
    }
}

#[test]
fn affinity_artifact_is_byte_identical_across_worker_counts_and_validates_v2() {
    let spec = FleetSpec::demo_affinity(120, 2);
    assert_eq!(schema_tag(&spec), "lime-fleet-v2");
    let reference = fleet_artifact_bytes(&spec, &run_fleet_sequential(&spec));
    for workers in [1usize, 4] {
        let pool = Pool::new(workers);
        let bytes = fleet_artifact_bytes(&spec, &run_fleet_on(&spec, Some(&pool)));
        assert_eq!(
            bytes, reference,
            "affinity fleet artifact differs at {workers} workers"
        );
    }
    let parsed = Json::parse(std::str::from_utf8(&reference).unwrap()).unwrap();
    let summary = validate_fleet(&parsed).expect("v2 artifact validates");
    assert_eq!(summary.schema, "lime-fleet-v2");
    assert_eq!(summary.name, "e3-demo-fleet-affinity");
    assert!(parsed.get("affinity").is_some(), "v2 must carry the affinity header");

    // Counters flow end-to-end: the Zipf(1.1) head revisits sessions
    // within 120 requests, so sticky routing must record hits, every hit
    // must reuse at least one resident token, and the per-shard counters
    // must sum to each cell's totals.
    let cells = run_fleet_sequential(&spec);
    let mut total_hits = 0u64;
    for cell in &cells {
        let aff = cell.affinity.expect("every v2 cell carries counters");
        assert!(aff.reuse_tokens_saved >= aff.hits, "a hit reuses >= 1 token");
        assert!(aff.hits <= cell.count as u64);
        let shard_hits: u64 = cell.shards.iter().map(|s| s.affinity_hits).sum();
        let shard_reuse: u64 = cell.shards.iter().map(|s| s.reuse_tokens_saved).sum();
        assert_eq!(shard_hits, aff.hits, "shard hit counters must sum to the cell");
        assert_eq!(shard_reuse, aff.reuse_tokens_saved);
        total_hits += aff.hits;
    }
    assert!(total_hits > 0, "the Zipf head must produce affinity hits");
}

#[test]
fn affinity_free_spec_still_serializes_as_v1() {
    // The singleton-downgrade rule end-to-end: no affinity on the spec
    // means the artifact is tagged v1 and carries no affinity header or
    // counter keys anywhere.
    let spec = FleetSpec::demo(60, 2);
    assert_eq!(schema_tag(&spec), "lime-fleet-v1");
    let bytes = fleet_artifact_bytes(&spec, &run_fleet_sequential(&spec));
    let text = std::str::from_utf8(&bytes).unwrap();
    let parsed = Json::parse(text).unwrap();
    assert_eq!(validate_fleet(&parsed).unwrap().schema, "lime-fleet-v1");
    assert!(parsed.get("affinity").is_none());
    assert!(!text.contains("affinity_hits"));
}

#[test]
fn mtbf_churn_drives_the_fleet_deterministically() {
    // Probabilistic (MTBF-driven) churn on cluster 1 only: the generated
    // timeline is a plain ChurnEvent list, so the fleet must stay
    // byte-identical across worker counts and validator-clean, re-route
    // counters included.
    let mut spec = FleetSpec::demo(120, 2);
    spec.churn = Script::churn_mtbf("mtbf-blip", 0xD1CE, 0.05, &[1], spec.count);
    assert!(
        spec.churn.churn.iter().any(|e| e.at_step < spec.count),
        "the MTBF script must actually fire within the stream"
    );
    let reference = fleet_artifact_bytes(&spec, &run_fleet_sequential(&spec));
    for workers in [1usize, 4] {
        let pool = Pool::new(workers);
        let bytes = fleet_artifact_bytes(&spec, &run_fleet_on(&spec, Some(&pool)));
        assert_eq!(
            bytes, reference,
            "MTBF-churned fleet artifact differs at {workers} workers"
        );
    }
    let parsed = Json::parse(std::str::from_utf8(&reference).unwrap()).unwrap();
    let summary = validate_fleet(&parsed).expect("MTBF-churned artifact validates");
    assert_eq!(summary.schema, "lime-fleet-v1");
    assert!(parsed.get("churn").is_some(), "churn header must be emitted");
    for cell in run_fleet_sequential(&spec) {
        let shard_sum: usize = cell.shards.iter().map(|s| s.count).sum();
        assert_eq!(shard_sum, spec.count, "churn re-routing must conserve requests");
    }
}
