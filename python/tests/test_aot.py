"""AOT path: every entry point lowers to parseable HLO text, the manifest is
self-consistent, and exported weight blobs match their declared shapes."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.config import CFG

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_entry_specs_cover_all_artifacts():
    specs = aot.entry_specs()
    assert set(specs) == {
        "embed_prefill",
        "embed_decode",
        "layer_prefill",
        "layer_decode",
        "mha_decode",
        "mlp_decode",
        "lm_head",
    }


def test_lowering_produces_hlo_text():
    specs = aot.entry_specs()
    fn, params = specs["mlp_decode"]
    lowered = jax.jit(fn).lower(*[s for _, s in params])
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_layer_decode_param_order_runs():
    """Calling the jitted fn with args in manifest order must reproduce the
    eager result — guards against param reordering between spec and fn."""
    specs = aot.entry_specs()
    fn, params = specs["layer_decode"]
    rng = np.random.default_rng(0)
    args = []
    for _, sds in params:
        if sds.dtype == jnp.int32:
            args.append(jnp.asarray(3, jnp.int32).reshape(sds.shape))
        else:
            args.append(
                jnp.asarray(rng.normal(0, 0.1, sds.shape), jnp.float32)
            )
    eager = fn(*args)
    jitted = jax.jit(fn)(*args)
    for a, b in zip(eager, jitted):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_model_config_matches(self, manifest):
        m = manifest["model"]
        assert m["layers"] == CFG.layers
        assert m["hidden"] == CFG.hidden
        assert m["kv_heads"] == CFG.kv_heads
        assert m["max_seq"] == CFG.max_seq

    def test_all_artifact_files_exist(self, manifest):
        for name, art in manifest["artifacts"].items():
            path = os.path.join(ART, art["file"])
            assert os.path.exists(path), name
            with open(path) as f:
                assert f.read(9) == "HloModule"

    def test_tensor_blobs_match_shapes(self, manifest):
        for name, t in manifest["tensors"].items():
            path = os.path.join(ART, t["file"])
            n = int(np.prod(t["shape"]))
            assert os.path.getsize(path) == 4 * n, name

    def test_layer_tensors_complete(self, manifest):
        for li in range(CFG.layers):
            for w in model.LAYER_WEIGHT_NAMES:
                assert f"layer{li}.{w}" in manifest["tensors"]

    def test_exported_weights_match_generator(self, manifest):
        w = model.make_weights(manifest["model"]["seed"])
        blob = np.fromfile(
            os.path.join(ART, manifest["tensors"]["layer0.wq"]["file"]),
            dtype=np.float32,
        ).reshape(manifest["tensors"]["layer0.wq"]["shape"])
        np.testing.assert_array_equal(blob, np.asarray(w["layer0"][1]))
