"""L1 correctness: Pallas decode-attention kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/lengths; every case asserts allclose against
`ref.gqa_decode_attention_ref`. This is the core numeric signal for the whole
stack: the same kernel is baked into layer_decode/mha_decode HLO artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import CHUNK, gqa_decode_attention
from compile.kernels.ref import (
    causal_prefill_attention_ref,
    gqa_decode_attention_ref,
)


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(
        dtype
    )


def run_case(num_heads, kv_heads, head_dim, max_seq, length, dtype, seed=0):
    q = rand(seed, (num_heads, head_dim), dtype)
    k = rand(seed + 1, (max_seq, kv_heads, head_dim), dtype)
    v = rand(seed + 2, (max_seq, kv_heads, head_dim), dtype)
    got = gqa_decode_attention(q, k, v, length)
    want = gqa_decode_attention_ref(q, k, v, length)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


# ---------------------------------------------------------------- fixed cases


def test_tinylm_shape_full_cache():
    run_case(8, 2, 16, 128, 128, jnp.float32)


def test_tinylm_shape_single_token():
    run_case(8, 2, 16, 128, 1, jnp.float32)


def test_chunk_boundary_lengths():
    for length in (CHUNK - 1, CHUNK, CHUNK + 1, 2 * CHUNK, 2 * CHUNK + 1):
        run_case(8, 2, 16, 4 * CHUNK, length, jnp.float32, seed=length)


def test_mha_no_gqa():
    # kv_heads == num_heads degenerates to plain MHA.
    run_case(4, 4, 16, CHUNK * 2, 37, jnp.float32)


def test_single_kv_head_mqa():
    # kv_heads == 1 degenerates to multi-query attention.
    run_case(8, 1, 32, CHUNK * 2, 50, jnp.float32)


def test_bf16_inputs():
    run_case(8, 2, 16, 128, 77, jnp.bfloat16)


def test_output_dtype_is_f32():
    q = rand(0, (8, 16), jnp.bfloat16)
    k = rand(1, (CHUNK, 2, 16), jnp.bfloat16)
    v = rand(2, (CHUNK, 2, 16), jnp.bfloat16)
    out = gqa_decode_attention(q, k, v, 5)
    assert out.dtype == jnp.float32


def test_masked_tail_is_ignored():
    # Garbage beyond `length` must not leak into the output.
    q = rand(0, (8, 16), jnp.float32)
    k = rand(1, (128, 2, 16), jnp.float32)
    v = rand(2, (128, 2, 16), jnp.float32)
    length = 40
    k_poison = k.at[length:].set(1e4)
    v_poison = v.at[length:].set(-1e4)
    a = gqa_decode_attention(q, k, v, length)
    b = gqa_decode_attention(q, k_poison, v_poison, length)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_softmax_rows_attend_correct_kv_head():
    # With v constant per KV head, output must equal that constant exactly
    # (softmax rows sum to 1), revealing any head-grouping mixups.
    num_heads, kv_heads, head_dim, max_seq = 8, 2, 16, 64
    q = rand(0, (num_heads, head_dim), jnp.float32)
    k = rand(1, (max_seq, kv_heads, head_dim), jnp.float32)
    v = jnp.stack(
        [jnp.full((max_seq, head_dim), float(i + 1)) for i in range(kv_heads)],
        axis=1,
    )
    out = gqa_decode_attention(q, k, v, 30)
    q_rep = num_heads // kv_heads
    for h in range(num_heads):
        expect = float(h // q_rep + 1)
        np.testing.assert_allclose(out[h], expect, rtol=1e-5)


# ------------------------------------------------------------ property sweep


@settings(max_examples=40, deadline=None)
@given(
    kv_heads=st.sampled_from([1, 2, 4]),
    q_rep=st.sampled_from([1, 2, 4]),
    head_dim=st.sampled_from([8, 16, 32]),
    chunks=st.integers(min_value=1, max_value=4),
    length_frac=st.floats(min_value=0.01, max_value=1.0),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_property_matches_ref(
    kv_heads, q_rep, head_dim, chunks, length_frac, dtype, seed
):
    max_seq = chunks * CHUNK
    length = max(1, int(length_frac * max_seq))
    run_case(kv_heads * q_rep, kv_heads, head_dim, max_seq, length, dtype, seed)


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_prefill_ref_is_causal(t, seed):
    # The prefill oracle must not attend to the future: perturbing token j
    # must not change outputs at positions < j.
    q = rand(seed, (t, 4, 8), jnp.float32)
    k = rand(seed + 1, (t, 2, 8), jnp.float32)
    v = rand(seed + 2, (t, 2, 8), jnp.float32)
    base = causal_prefill_attention_ref(q, k, v, 2)
    if t < 2:
        return
    j = t - 1
    k2 = k.at[j].set(k[j] + 3.0)
    v2 = v.at[j].set(v[j] - 3.0)
    pert = causal_prefill_attention_ref(q, k2, v2, 2)
    np.testing.assert_allclose(base[:j], pert[:j], rtol=1e-6, atol=1e-6)


def test_rejects_non_chunk_multiple():
    q = rand(0, (4, 8), jnp.float32)
    k = rand(1, (CHUNK + 1, 2, 8), jnp.float32)
    v = rand(2, (CHUNK + 1, 2, 8), jnp.float32)
    with pytest.raises(AssertionError):
        gqa_decode_attention(q, k, v, 3)
