"""L2 correctness: TinyLM entry points — shapes, composition identities,
determinism, and the block-split (fine-grained offload) equivalence that the
Rust losslessness checker relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import CFG


@pytest.fixture(scope="module")
def weights():
    return model.make_weights(seed=0)


def layer_w(weights, li=0):
    return weights[f"layer{li}"]


def fresh_caches():
    kc = jnp.zeros((1, CFG.max_seq, CFG.kv_heads, CFG.head_dim), jnp.float32)
    return kc, jnp.zeros_like(kc)


# ------------------------------------------------------------------- shapes


def test_embed_prefill_shape(weights):
    toks = jnp.arange(CFG.prefill_len, dtype=jnp.int32)[None, :]
    (x,) = model.embed_prefill(toks, weights["embed"])
    assert x.shape == (1, CFG.prefill_len, CFG.hidden)


def test_embed_decode_shape(weights):
    (x,) = model.embed_decode(jnp.zeros((1, 1), jnp.int32), weights["embed"])
    assert x.shape == (1, 1, CFG.hidden)


def test_layer_prefill_shapes(weights):
    x = jnp.ones((1, CFG.prefill_len, CFG.hidden), jnp.float32) * 0.1
    y, k, v = model.layer_prefill(x, *layer_w(weights))
    assert y.shape == x.shape
    assert k.shape == (1, CFG.prefill_len, CFG.kv_heads, CFG.head_dim)
    assert v.shape == k.shape


def test_layer_decode_shapes(weights):
    x = jnp.ones((1, 1, CFG.hidden), jnp.float32) * 0.1
    kc, vc = fresh_caches()
    y, kc2, vc2 = model.layer_decode(x, kc, vc, jnp.int32(0), *layer_w(weights))
    assert y.shape == x.shape
    assert kc2.shape == kc.shape and vc2.shape == vc.shape


def test_lm_head_shape(weights):
    x = jnp.ones((1, 1, CFG.hidden), jnp.float32)
    (logits,) = model.lm_head(x, weights["ln_f"], weights["lm_head"])
    assert logits.shape == (1, CFG.vocab)


# ------------------------------------------------- composition identities


def test_layer_decode_equals_mha_then_mlp(weights):
    """Fine-grained offload path (MHA block + MLP block executed separately)
    must be bit-identical to the fused layer artifact."""
    w = layer_w(weights)
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 1, CFG.hidden))
    kc, vc = fresh_caches()
    pos = jnp.int32(3)

    y_full, kc_full, vc_full = model.layer_decode(x, kc, vc, pos, *w)
    y_mha, kc_b, vc_b = model.mha_decode(x, kc, vc, pos, *w[:5])
    (y_split,) = model.mlp_decode(y_mha, *w[5:])

    np.testing.assert_array_equal(np.asarray(y_full), np.asarray(y_split))
    np.testing.assert_array_equal(np.asarray(kc_full), np.asarray(kc_b))
    np.testing.assert_array_equal(np.asarray(vc_full), np.asarray(vc_b))


def test_decode_matches_prefill_position(weights):
    """Token-by-token decode must reproduce the prefill computation: feeding
    the same prompt through layer_prefill and through successive layer_decode
    calls must yield the same final hidden state."""
    w = layer_w(weights)
    p = CFG.prefill_len
    toks = (jnp.arange(p, dtype=jnp.int32) * 7) % CFG.vocab
    (x,) = model.embed_prefill(toks[None, :], weights["embed"])
    y_pref, k_pref, v_pref = model.layer_prefill(x, *w)

    kc, vc = fresh_caches()
    ys = []
    for t in range(p):
        (xt,) = model.embed_decode(toks[t].reshape(1, 1), weights["embed"])
        yt, kc, vc = model.layer_decode(xt, kc, vc, jnp.int32(t), *w)
        ys.append(yt[:, 0, :])
    y_dec = jnp.stack(ys, axis=1)

    np.testing.assert_allclose(
        np.asarray(y_pref), np.asarray(y_dec), rtol=2e-4, atol=2e-4
    )
    # The caches the decode path built must match prefill's returned KV.
    np.testing.assert_allclose(
        np.asarray(kc[:, :p]), np.asarray(k_pref), rtol=2e-5, atol=2e-5
    )
    np.testing.assert_allclose(
        np.asarray(vc[:, :p]), np.asarray(v_pref), rtol=2e-5, atol=2e-5
    )


def test_cache_slots_beyond_pos_untouched(weights):
    w = layer_w(weights)
    x = jnp.ones((1, 1, CFG.hidden)) * 0.2
    kc, vc = fresh_caches()
    kc = kc.at[:, 10:].set(42.0)
    _, kc2, _ = model.layer_decode(x, kc, vc, jnp.int32(4), *w)
    np.testing.assert_array_equal(np.asarray(kc2[:, 10:]), 42.0)


# ------------------------------------------------------------ whole model


def test_forward_greedy_deterministic(weights):
    prompt = (jnp.arange(CFG.prefill_len, dtype=jnp.int32) * 3) % CFG.vocab
    a = model.forward_greedy(weights, prompt, 6)
    b = model.forward_greedy(weights, prompt, 6)
    assert a == b
    assert len(a) == 6
    assert all(0 <= t < CFG.vocab for t in a)


def test_forward_greedy_prompt_sensitivity(weights):
    p1 = (jnp.arange(CFG.prefill_len, dtype=jnp.int32) * 3) % CFG.vocab
    p2 = (jnp.arange(CFG.prefill_len, dtype=jnp.int32) * 5 + 1) % CFG.vocab
    assert model.forward_greedy(weights, p1, 6) != model.forward_greedy(
        weights, p2, 6
    )


def test_weights_deterministic_by_seed():
    w1 = model.make_weights(seed=0)
    w2 = model.make_weights(seed=0)
    w3 = model.make_weights(seed=1)
    np.testing.assert_array_equal(np.asarray(w1["embed"]), np.asarray(w2["embed"]))
    assert not np.array_equal(np.asarray(w1["embed"]), np.asarray(w3["embed"]))


# ---------------------------------------------------------------- rmsnorm


def test_rmsnorm_unit_scale():
    x = jnp.ones((1, 4, 8)) * 3.0
    y = model.rmsnorm(x, jnp.ones((8,)))
    np.testing.assert_allclose(np.asarray(y), 1.0, rtol=1e-4)


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 4, 16))
    y = model.apply_rope(x, jnp.arange(5, dtype=jnp.int32))
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_position_zero_identity():
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 16))
    y = model.apply_rope(x, jnp.zeros((1,), jnp.int32))
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)
