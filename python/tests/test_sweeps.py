"""Consumer-side tests for the ``lime-sweep-v2``..``v7`` artifacts:
loading, figure-layout rendering, the request-level serving table, the
batching-policy comparison table, the device-churn recovery-latency
table, the workload-mix length table, and the speedup summary — against
small hand-built grids mirroring what ``lime experiments --id sweep``
emits (v7) and what older checkouts emitted (v2/v3/v4/v5/v6)."""

import json

import pytest

from sweeps import figures


def _cell(method, name, bw, pattern, seg, mem, ms, **extra):
    cell = {
        "method": method,
        "method_name": name,
        "bandwidth_mbps": bw,
        "pattern": pattern,
        "seg": seg,
        "mem": mem,
        "planned_seg": extra.get("planned_seg"),
        "ms_per_token": ms,
        "oom": ms is None,
        "oot": extra.get("oot", False),
        "online_plans_fired": None if ms is None else extra.get("plans", 0),
        "kv_tokens_transferred": None if ms is None else extra.get("kv", 0),
        "emergency_steps": None if ms is None else extra.get("emergency", 0),
    }
    return cell


@pytest.fixture
def sweep_dir(tmp_path):
    cells = []
    for pattern in ("sporadic", "bursty"):
        # LIME: full seg × mem cross at one bandwidth.
        for seg, planned in (("auto", 6), (4, 4)):
            for mem, plans in (("none", 0), ("squeeze-d0", 3)):
                cells.append(
                    _cell(
                        "lime", "LIME", 200.0, pattern, seg, mem,
                        100.0 + plans * 10.0,
                        planned_seg=planned, plans=plans, kv=plans * 8,
                    )
                )
        # Baselines: baseline point only.
        cells.append(_cell("pp", "Pipeline parallelism", 200.0, pattern, "auto", "none", 250.0))
        cells.append(_cell("galaxy", "Galaxy", 200.0, pattern, "auto", "none", None))
    doc = {
        "schema": "lime-sweep-v2",
        "grid": "testgrid",
        "model": "Llama3.3-70B-Instruct",
        "tokens": 16,
        "bandwidths_mbps": [200.0],
        "axes": {
            "cluster": {"label": "testgrid", "devices": ["AGXOrin-64G", "XavierNX-16G"]},
            "bandwidths_mbps": [200.0],
            "patterns": ["sporadic", "bursty"],
            "methods": ["lime", "pp", "galaxy"],
            "segs": ["auto", 4],
            "mem_scenarios": [
                {"label": "none", "events": []},
                {
                    "label": "squeeze-d0",
                    "events": [{"at_step": 4, "device": 0, "delta_bytes": -4e9}],
                },
            ],
        },
        "cells": cells,
    }
    path = tmp_path / "SWEEP_testgrid.json"
    path.write_text(json.dumps(doc))
    return tmp_path


def test_load_sweeps_parses_grid(sweep_dir):
    grids = figures.load_sweeps(str(sweep_dir))
    assert len(grids) == 1
    g = grids[0]
    assert g.grid == "testgrid"
    assert g.tokens == 16
    # Baseline point: 3 methods × 2 patterns at (auto, none).
    assert len(g.baseline_cells()) == 6
    assert len(g.lime_cells()) == 8


def test_load_rejects_wrong_schema(tmp_path):
    bad = tmp_path / "SWEEP_bad.json"
    bad.write_text(json.dumps({"schema": "lime-sweep-v1", "cells": []}))
    with pytest.raises(ValueError, match="lime-sweep-v2"):
        figures.load_grid(str(bad))


@pytest.fixture
def sweep_dir_v3(tmp_path):
    """A minimal lime-sweep-v3 artifact: joint pressure scripts with full
    metadata and the per-cell bandwidth-stall counter."""
    def v3_cell(method, name, mem, ms, stalls, plans=0):
        cell = _cell(method, name, 200.0, "sporadic", "auto", mem, ms, plans=plans)
        cell["bw_stalls"] = None if ms is None else stalls
        return cell

    cells = [
        v3_cell("lime", "LIME", "none", 100.0, 2),
        v3_cell("lime", "LIME", "joint-sag-squeeze", 140.0, 17, plans=3),
        v3_cell("pp", "Pipeline parallelism", "none", 250.0, 1),
    ]
    # An OOM LIME cell: its null counters must render as "-", not "None".
    # (The consumer does not enforce coordinate uniqueness, so reusing the
    # scenario at another bandwidth-free coordinate is fine here.)
    oom = v3_cell("lime", "LIME", "joint-sag-squeeze", None, 0)
    oom["pattern"] = "bursty"
    cells.append(oom)
    doc = {
        "schema": "lime-sweep-v3",
        "grid": "v3grid",
        "model": "Qwen3-32B",
        "tokens": 8,
        "bandwidths_mbps": [200.0],
        "axes": {
            "cluster": {"label": "v3grid", "devices": ["AGXOrin-64G", "AGXOrin-32G"]},
            "bandwidths_mbps": [200.0],
            "patterns": ["sporadic"],
            "methods": ["lime", "pp"],
            "segs": ["auto"],
            "mem_scenarios": [
                {"label": "none", "events": []},
                {
                    "label": "joint-sag-squeeze",
                    "events": [{"at_step": 2, "device": 0, "delta_bytes": -4e9}],
                },
            ],
            "pressure_scripts": [
                {"label": "none", "mem_events": [], "bw_events": []},
                {
                    "label": "joint-sag-squeeze",
                    "mem_events": [
                        {"at_step": 2, "device": 0, "delta_bytes": -4e9}
                    ],
                    "bw_events": [
                        {"at_step": 2, "scale": 0.5},
                        {"at_step": 6, "scale": 1.0},
                    ],
                },
            ],
        },
        "cells": cells,
    }
    path = tmp_path / "SWEEP_v3grid.json"
    path.write_text(json.dumps(doc))
    return tmp_path


def test_v3_artifact_loads_and_renders_link_stalls(sweep_dir_v3):
    g = figures.load_sweeps(str(sweep_dir_v3))[0]
    assert g.grid == "v3grid"
    text = figures.fig_memory_fluctuation(g)
    assert "link stalls" in text, "v3 artifacts must render the stall column"
    assert "joint-sag-squeeze" in text
    assert "| 17 |" in text, "the joint cell's stall count must render"
    # OOM cells render "-" for their null counters, never "None".
    assert "OOM" in text
    assert "None" not in text
    # The full render still works end to end on a v3 artifact.
    assert figures.render_grid(g).count("##") >= 4


def test_v2_artifact_renders_without_stall_column(sweep_dir):
    g = figures.load_sweeps(str(sweep_dir))[0]
    assert "link stalls" not in figures.fig_memory_fluctuation(g)


def test_latency_table_marks_oom(sweep_dir):
    g = figures.load_sweeps(str(sweep_dir))[0]
    text = figures.fig_latency_vs_bandwidth(g)
    assert "LIME" in text and "100.0" in text
    assert "OOM" in text, "Galaxy's OOM must render"
    assert "200 Mbps" in text


def test_seg_curve_reports_auto_pick(sweep_dir):
    g = figures.load_sweeps(str(sweep_dir))[0]
    text = figures.fig_seg_curve(g)
    assert "(seg=6)" in text, "auto column must report the scheduler's pick"
    assert "#Seg=4" in text


def test_memory_fluctuation_surfaces_adaptation(sweep_dir):
    g = figures.load_sweeps(str(sweep_dir))[0]
    text = figures.fig_memory_fluctuation(g)
    assert "squeeze-d0" in text
    # The squeezed cells fired 3 plans and shipped 24 KV tokens.
    assert "| 3 |" in text and "| 24 |" in text


def test_speedup_summary_uses_best_completing_baseline(sweep_dir):
    g = figures.load_sweeps(str(sweep_dir))[0]
    text = figures.speedup_summary(g)
    # pp at 250 ms vs LIME at 100 ms -> 2.50x; Galaxy (OOM) excluded.
    assert "2.50x" in text
    assert "Galaxy" not in text


@pytest.fixture
def sweep_dir_v4(tmp_path):
    """A minimal lime-sweep-v4 artifact: the arrival-process axis with a
    3-request stream point carrying per-request metric arrays."""

    def v4_cell(method, name, pattern, arrival, ms, requests=None):
        cell = _cell(method, name, 200.0, pattern, "auto", "none", ms)
        cell["bw_stalls"] = None if ms is None else 1
        cell["arrival"] = arrival
        cell["requests"] = requests
        return cell

    stream = {
        "queueing_delay_s": [0.0, 2.5, 5.0],
        "ttft_s": [1.0, 3.5, 6.0],
        "tbt_s": [0.25, 0.25, 0.25],
    }
    spread = {
        "queueing_delay_s": [0.0, 0.0, 0.5],
        "ttft_s": [1.0, 1.1, 1.6],
        "tbt_s": [0.25, 0.25, 0.25],
    }
    cells = [
        v4_cell("lime", "LIME", "sporadic", "single", 100.0),
        v4_cell("lime", "LIME", "bursty", "single", 90.0),
        v4_cell("lime", "LIME", "sporadic", "stream3", 100.0, requests=spread),
        v4_cell("lime", "LIME", "bursty", "stream3", 95.0, requests=stream),
        v4_cell("pp", "Pipeline parallelism", "sporadic", "single", 250.0),
        v4_cell("pp", "Pipeline parallelism", "bursty", "single", 240.0),
    ]
    doc = {
        "schema": "lime-sweep-v4",
        "grid": "v4grid",
        "model": "Qwen3-32B",
        "tokens": 8,
        "bandwidths_mbps": [200.0],
        "axes": {
            "cluster": {"label": "v4grid", "devices": ["AGXOrin-64G", "AGXOrin-32G"]},
            "bandwidths_mbps": [200.0],
            "patterns": ["sporadic", "bursty"],
            "methods": ["lime", "pp"],
            "segs": ["auto"],
            "mem_scenarios": [{"label": "none", "events": []}],
            "pressure_scripts": [{"label": "none", "mem_events": [], "bw_events": []}],
            "arrivals": [
                {"label": "single", "kind": "single"},
                {"label": "stream3", "kind": "stream", "count": 3, "lambda": 0.5},
            ],
        },
        "cells": cells,
    }
    path = tmp_path / "SWEEP_v4grid.json"
    path.write_text(json.dumps(doc))
    return tmp_path


def test_v4_artifact_loads_and_renders_queueing_table(sweep_dir_v4):
    g = figures.load_sweeps(str(sweep_dir_v4))[0]
    assert g.grid == "v4grid"
    assert len(g.stream_cells()) == 2
    text = figures.fig_queueing_delay(g)
    assert "stream3" in text
    # Bursty stream: mean qd (0+2.5+5)/3 = 2.5, max 5.0, mean TTFT 3.5,
    # TBT 250 ms.
    assert "| 2.500 |" in text
    assert "| 5.000 |" in text
    assert "| 3.500 |" in text
    assert "| 250.0 |" in text
    # Full render includes the serving section exactly once.
    rendered = figures.render_grid(g)
    assert rendered.count("request-level serving metrics") == 1


def test_v4_stream_cells_do_not_pollute_single_run_figures(sweep_dir_v4):
    g = figures.load_sweeps(str(sweep_dir_v4))[0]
    # Baseline tables must use the single-run cells only: 2 methods × 2
    # patterns at (auto, none, single).
    assert len(g.baseline_cells()) == 4
    text = figures.fig_latency_vs_bandwidth(g)
    # The sporadic LIME column shows the single-run 100.0, and the bursty
    # one the single-run 90.0 (not the stream 95.0).
    assert "100.0" in text and "90.0" in text
    assert "95.0" not in text
    # Speedup summary compares single-run cells: 250/100 = 2.50x.
    assert "2.50x" in figures.speedup_summary(g)


def test_pre_v4_grids_render_without_serving_section(sweep_dir):
    g = figures.load_sweeps(str(sweep_dir))[0]
    assert g.stream_cells() == []
    assert g.churn_labels() == []
    rendered = figures.render_grid(g)
    assert "request-level serving metrics" not in rendered
    assert "recovery latency" not in rendered


@pytest.fixture
def sweep_dir_v5(tmp_path):
    """A minimal lime-sweep-v5 artifact: the device-churn axis with one
    Down/Up blip, LIME recovering (re-plans, KV migrated, finite recovery
    steps) and the churn-capable EdgeShard baseline riding the same fault
    out degraded (a null recovery slot); the rigid pp baseline stays
    pinned to the no-churn point."""

    def v5_cell(method, name, churn, ms, replans=0, kv_mig=0, recovery=()):
        cell = _cell(method, name, 200.0, "sporadic", "auto", "none", ms)
        cell["bw_stalls"] = None if ms is None else 0
        cell["arrival"] = "single"
        cell["churn"] = churn
        cell["replans_fired"] = None if ms is None else replans
        cell["kv_migrated_bytes"] = None if ms is None else kv_mig
        cell["recovery_steps"] = None if ms is None else list(recovery)
        return cell

    cells = [
        v5_cell("lime", "LIME", "none", 100.0),
        v5_cell("lime", "LIME", "blip-d1", 130.0, replans=2, kv_mig=4096, recovery=(3,)),
        v5_cell("edgeshard", "EdgeShard", "none", 150.0),
        v5_cell("edgeshard", "EdgeShard", "blip-d1", 210.0, recovery=(None,)),
        v5_cell("pp", "Pipeline parallelism", "none", 250.0),
    ]
    doc = {
        "schema": "lime-sweep-v5",
        "grid": "v5grid",
        "model": "Qwen3-32B",
        "tokens": 12,
        "bandwidths_mbps": [200.0],
        "axes": {
            "cluster": {"label": "v5grid", "devices": ["AGXOrin-64G", "XavierNX-16G"]},
            "bandwidths_mbps": [200.0],
            "patterns": ["sporadic"],
            "methods": ["lime", "edgeshard", "pp"],
            "segs": ["auto"],
            "mem_scenarios": [{"label": "none", "events": []}],
            "pressure_scripts": [{"label": "none", "mem_events": [], "bw_events": []}],
            "arrivals": [{"label": "single", "kind": "single"}],
            "churn_scripts": [
                {"label": "none", "events": []},
                {
                    "label": "blip-d1",
                    "events": [
                        {"at_step": 4, "device": 1, "kind": "down"},
                        {"at_step": 8, "device": 1, "kind": "up"},
                    ],
                },
            ],
        },
        "cells": cells,
    }
    path = tmp_path / "SWEEP_v5grid.json"
    path.write_text(json.dumps(doc))
    return tmp_path


def test_v5_artifact_loads_and_renders_recovery_table(sweep_dir_v5):
    g = figures.load_sweeps(str(sweep_dir_v5))[0]
    assert g.grid == "v5grid"
    assert g.baseline_churn == "none"
    assert g.churn_labels() == ["blip-d1"]
    text = figures.fig_recovery_latency(g)
    # LIME recovered: 2 re-plans, 4096 B migrated, 3 steps to recover,
    # with the no-churn twin latency alongside the churned one.
    assert "| 100.0 | 130.0 | 2 | 4096 | 3 |" in text
    # EdgeShard rode the fault out: zero recovery machinery and a
    # degraded (em-dash) recovery slot, never "None".
    assert "| 150.0 | 210.0 | 0 | 0 | — |" in text
    assert "None" not in text
    # The rigid baseline is pinned to the no-churn point and drops out.
    assert "Pipeline parallelism" not in text


def test_v5_churned_cells_do_not_pollute_baseline_figures(sweep_dir_v5):
    g = figures.load_sweeps(str(sweep_dir_v5))[0]
    # Baseline point: 3 methods at (auto, none, single, no-churn).
    assert len(g.baseline_cells()) == 3
    text = figures.fig_latency_vs_bandwidth(g)
    assert "100.0" in text and "150.0" in text and "250.0" in text
    assert "130.0" not in text and "210.0" not in text
    # Speedup compares fault-free cells only: 150/100 = 1.50x.
    assert "1.50x" in figures.speedup_summary(g)


def test_v5_render_grid_includes_recovery_section_once(sweep_dir_v5):
    g = figures.load_sweeps(str(sweep_dir_v5))[0]
    rendered = figures.render_grid(g)
    assert rendered.count("recovery latency under device churn") == 1


@pytest.fixture
def sweep_dir_v6(tmp_path):
    """A minimal lime-sweep-v6 artifact: the batching-policy axis with a
    FIFO/continuous twin pair on one bursty stream column — the
    continuous cell admits between decode steps (lower queueing/TTFT)
    and carries the paged-KV counters; the FIFO twin never touches the
    page pool, so its counters are exactly zero."""

    def v6_cell(method, name, arrival, batching, ms, requests=None, **kv):
        cell = _cell(method, name, 200.0, "bursty", "auto", "none", ms)
        cell["bw_stalls"] = None if ms is None else 0
        cell["arrival"] = arrival
        cell["requests"] = requests
        cell["churn"] = "none"
        cell["replans_fired"] = None if ms is None else 0
        cell["kv_migrated_bytes"] = None if ms is None else 0
        cell["recovery_steps"] = None if ms is None else []
        cell["batching"] = batching
        cell["kv_pages_allocated"] = None if ms is None else kv.get("pages", 0)
        cell["kv_pages_spilled"] = None if ms is None else kv.get("spilled", 0)
        cell["fragmentation"] = None if ms is None else kv.get("frag", 0.0)
        return cell

    fifo_stream = {
        "queueing_delay_s": [0.0, 2.5, 5.0],
        "ttft_s": [1.0, 3.5, 6.0],
        "tbt_s": [0.25, 0.25, 0.25],
    }
    cont_stream = {
        "queueing_delay_s": [0.0, 0.8, 1.6],
        "ttft_s": [1.0, 1.9, 2.7],
        "tbt_s": [0.25, 0.25, 0.25],
    }
    cells = [
        v6_cell("lime", "LIME", "single", "fifo", 100.0),
        v6_cell("lime", "LIME", "stream3", "fifo", 95.0, requests=fifo_stream),
        v6_cell(
            "lime", "LIME", "stream3", "cont16", 93.0,
            requests=cont_stream, pages=12, spilled=2, frag=0.25,
        ),
        v6_cell("pp", "Pipeline parallelism", "single", "fifo", 250.0),
    ]
    doc = {
        "schema": "lime-sweep-v6",
        "grid": "v6grid",
        "model": "Qwen3-32B",
        "tokens": 8,
        "bandwidths_mbps": [200.0],
        "axes": {
            "cluster": {"label": "v6grid", "devices": ["AGXOrin-64G", "AGXOrin-32G"]},
            "bandwidths_mbps": [200.0],
            "patterns": ["bursty"],
            "methods": ["lime", "pp"],
            "segs": ["auto"],
            "mem_scenarios": [{"label": "none", "events": []}],
            "pressure_scripts": [{"label": "none", "mem_events": [], "bw_events": []}],
            "arrivals": [
                {"label": "single", "kind": "single"},
                {"label": "stream3", "kind": "stream", "count": 3, "lambda": 0.5},
            ],
            "churn_scripts": [{"label": "none", "events": []}],
            "batching": [
                {"label": "fifo", "mode": "fifo"},
                {"label": "cont16", "mode": "continuous", "page_tokens": 16},
            ],
        },
        "cells": cells,
    }
    path = tmp_path / "SWEEP_v6grid.json"
    path.write_text(json.dumps(doc))
    return tmp_path


def test_v6_artifact_loads_and_renders_batching_table(sweep_dir_v6):
    g = figures.load_sweeps(str(sweep_dir_v6))[0]
    assert g.grid == "v6grid"
    assert g.baseline_batching == "fifo"
    assert g.batching_labels() == ["fifo", "cont16"]
    text = figures.fig_batching(g)
    # The FIFO row: mean qd (0+2.5+5)/3 = 2.5 and zero page counters.
    assert "| fifo |" in text
    assert "| 2.500 |" in text
    assert "| 0 | 0 | 0.000 |" in text
    # The continuous twin: mean qd 0.8, mean TTFT 1.867, and its paged-KV
    # counters (12 pages, 2 spilled, peak fragmentation 0.25).
    assert "| cont16 |" in text
    assert "| 0.800 |" in text
    assert "| 1.867 |" in text
    assert "| 12 | 2 | 0.250 |" in text
    assert "None" not in text


def test_v6_continuous_cells_do_not_pollute_older_figures(sweep_dir_v6):
    g = figures.load_sweeps(str(sweep_dir_v6))[0]
    # The v4 queueing table shows the FIFO stream only; the continuous
    # twin lives in fig_batching.
    text = figures.fig_queueing_delay(g)
    assert "| 2.500 |" in text
    assert "0.800" not in text
    # Baseline figures use single-run cells (always FIFO): 2 methods.
    assert len(g.baseline_cells()) == 2
    assert "2.50x" in figures.speedup_summary(g)
    # The full render includes the batching section exactly once.
    rendered = figures.render_grid(g)
    assert rendered.count("FIFO vs continuous batching") == 1


def test_pre_v6_grids_render_without_batching_section(sweep_dir_v5):
    g = figures.load_sweeps(str(sweep_dir_v5))[0]
    assert g.baseline_batching == "fifo"
    assert g.batching_labels() == ["fifo"]
    assert "FIFO vs continuous batching" not in figures.render_grid(g)


@pytest.fixture
def sweep_dir_v7(tmp_path):
    """A minimal lime-sweep-v7 artifact: the workload-mix axis with a
    fixed-length / bimodal twin pair on one bursty stream column — the
    mixed cell's request arrays carry ragged per-request
    ``prompt_len``/``steps``, the fixed twin's are constant."""

    def v7_cell(method, name, arrival, workload, ms, requests=None):
        cell = _cell(method, name, 200.0, "bursty", "auto", "none", ms)
        cell["bw_stalls"] = None if ms is None else 0
        cell["arrival"] = arrival
        cell["requests"] = requests
        cell["churn"] = "none"
        cell["replans_fired"] = None if ms is None else 0
        cell["kv_migrated_bytes"] = None if ms is None else 0
        cell["recovery_steps"] = None if ms is None else []
        cell["batching"] = "fifo"
        cell["kv_pages_allocated"] = None if ms is None else 0
        cell["kv_pages_spilled"] = None if ms is None else 0
        cell["fragmentation"] = None if ms is None else 0.0
        cell["workload"] = workload
        return cell

    fixed_stream = {
        "queueing_delay_s": [0.0, 2.5, 5.0],
        "ttft_s": [1.0, 3.5, 6.0],
        "tbt_s": [0.25, 0.25, 0.25],
        "prompt_len": [64, 64, 64],
        "steps": [3, 3, 3],
    }
    mixed_stream = {
        "queueing_delay_s": [0.0, 3.0, 7.0],
        "ttft_s": [1.0, 4.0, 8.5],
        "tbt_s": [0.25, 0.25, 0.25],
        "prompt_len": [32, 128, 32],
        "steps": [2, 8, 2],
    }
    cells = [
        v7_cell("lime", "LIME", "single", "fixed", 100.0),
        v7_cell("lime", "LIME", "stream3", "fixed", 95.0, requests=fixed_stream),
        v7_cell("lime", "LIME", "stream3", "bimix50", 105.0, requests=mixed_stream),
        v7_cell("pp", "Pipeline parallelism", "single", "fixed", 250.0),
    ]
    doc = {
        "schema": "lime-sweep-v7",
        "grid": "v7grid",
        "model": "Qwen3-32B",
        "tokens": 8,
        "bandwidths_mbps": [200.0],
        "axes": {
            "cluster": {"label": "v7grid", "devices": ["AGXOrin-64G", "AGXOrin-32G"]},
            "bandwidths_mbps": [200.0],
            "patterns": ["bursty"],
            "methods": ["lime", "pp"],
            "segs": ["auto"],
            "mem_scenarios": [{"label": "none", "events": []}],
            "pressure_scripts": [{"label": "none", "mem_events": [], "bw_events": []}],
            "arrivals": [
                {"label": "single", "kind": "single"},
                {"label": "stream3", "kind": "stream", "count": 3, "lambda": 0.5},
            ],
            "churn_scripts": [{"label": "none", "events": []}],
            "batching": [{"label": "fifo", "mode": "fifo"}],
            "workloads": [
                {"label": "fixed", "kind": "fixed", "prompt_tokens": 64, "steps": 3},
                {
                    "label": "bimix50",
                    "kind": "bimodal",
                    "short_prompt": 32,
                    "short_steps": 2,
                    "long_prompt": 128,
                    "long_steps": 8,
                    "long_frac": 0.5,
                },
            ],
        },
        "cells": cells,
    }
    path = tmp_path / "SWEEP_v7grid.json"
    path.write_text(json.dumps(doc))
    return tmp_path


def test_v7_artifact_loads_and_renders_length_mix_table(sweep_dir_v7):
    g = figures.load_sweeps(str(sweep_dir_v7))[0]
    assert g.grid == "v7grid"
    assert g.baseline_workload == "fixed"
    assert g.workload_labels() == ["fixed", "bimix50"]
    text = figures.fig_length_mix(g)
    # The fixed row: degenerate spreads and the v4-table serving metrics.
    assert "| fixed |" in text
    assert "| 64/64/64 |" in text and "| 3/3/3 |" in text
    assert "| 2.500 |" in text
    # The bimodal twin: ragged min/mean/max spreads from the per-request
    # arrays, mean qd (0+3+7)/3 and mean TTFT (1+4+8.5)/3.
    assert "| bimix50 |" in text
    assert "| 32/64/128 |" in text and "| 2/4/8 |" in text
    assert "| 3.333 |" in text
    assert "| 4.500 |" in text
    assert "None" not in text


def test_v7_mixed_cells_do_not_pollute_older_figures(sweep_dir_v7):
    g = figures.load_sweeps(str(sweep_dir_v7))[0]
    # The v4 queueing table pins the baseline (fixed) workload only; the
    # mixed twin lives in fig_length_mix.
    text = figures.fig_queueing_delay(g)
    assert "| 2.500 |" in text
    assert "3.333" not in text
    # Baseline figures use single-run cells (always fixed): 2 methods.
    assert len(g.baseline_cells()) == 2
    assert "2.50x" in figures.speedup_summary(g)
    # The full render includes the workload section exactly once.
    rendered = figures.render_grid(g)
    assert rendered.count("fixed vs mixed-length workloads") == 1


def test_pre_v7_grids_render_without_workload_section(sweep_dir_v6):
    g = figures.load_sweeps(str(sweep_dir_v6))[0]
    # Pre-v7 cells carry no "workload" key: everything sits at the
    # implicit fixed baseline and the length-mix section is omitted.
    assert g.baseline_workload == "fixed"
    assert g.workload_labels() == ["fixed"]
    assert all(g.at_baseline_workload(c) for c in g.cells)
    assert "fixed vs mixed-length workloads" not in figures.render_grid(g)


def test_render_grid_and_cli(sweep_dir, tmp_path, capsys):
    g = figures.load_sweeps(str(sweep_dir))[0]
    assert figures.render_grid(g).count("##") >= 4
    out = tmp_path / "figs"
    rc = figures.main([str(sweep_dir), "--out", str(out)])
    assert rc == 0
    assert (out / "testgrid.md").exists()
    assert "wrote" in capsys.readouterr().out


def _stat(mean, p50, p95, p99):
    return {"mean": mean, "p50": p50, "p95": p95, "p99": p99}


def _fleet_cell(router, pattern, count, shard_counts):
    """One (router, pattern) cell in the exact shape `lime fleet` emits."""
    return {
        "count": count,
        "makespan_s": 4.25,
        "pattern": pattern,
        "per_cluster": [
            {
                "count": n,
                "decode_s": 0.5 * n,
                "label": label,
                "makespan_s": 4.25 if n else 0.0,
                "queueing_delay_s": _stat(0.1, 0.05, 0.3, 0.4),
                "tbt_s": _stat(0.02, 0.02, 0.03, 0.03),
                "ttft_s": _stat(0.2, 0.15, 0.5, 0.6),
            }
            for label, n in shard_counts
        ],
        "queueing_delay_s": _stat(0.1, 0.05, 0.3, 0.456),
        "router": router,
        "tbt_s": _stat(0.025, 0.02, 0.03, 0.035),
        "ttft_s": _stat(0.25, 0.125, 0.5, 0.75),
    }


@pytest.fixture
def fleet_dir(tmp_path):
    """A minimal lime-fleet-v1 artifact: two clusters, two routers, one
    pattern — the streamed shape `lime fleet` writes."""
    shard_counts = [("orin2", 3), ("edge2", 1)]
    doc = {
        "cells": [
            _fleet_cell("rr", "sporadic", 4, shard_counts),
            _fleet_cell("jsq", "sporadic", 4, [("orin2", 4), ("edge2", 0)]),
        ],
        "clusters": [
            {"bw_mbps": 100.0, "devices": 2, "label": "orin2", "planned_ms_per_token": 83.0},
            {"bw_mbps": 150.0, "devices": 2, "label": "edge2", "planned_ms_per_token": 61.5},
        ],
        "count": 4,
        "lambda": 200.0,
        "model": "Qwen3-32B",
        "name": "fixture-fleet",
        "patterns": ["sporadic"],
        "routers": ["rr", "jsq"],
        "schema": "lime-fleet-v1",
        "seed": 1,
        "steps": 4,
    }
    path = tmp_path / "FLEET_fixture-fleet.json"
    path.write_text(json.dumps(doc))
    return tmp_path


def test_load_fleets_parses_artifact(fleet_dir):
    fleets = figures.load_fleets(str(fleet_dir))
    assert len(fleets) == 1
    f = fleets[0]
    assert f.name == "fixture-fleet"
    assert f.model == "Qwen3-32B"
    assert f.routers == ["rr", "jsq"]
    assert len(f.cells) == 2


def test_load_fleets_is_empty_when_absent(sweep_dir):
    # A sweeps-only directory yields no fleets (and no error).
    assert figures.load_fleets(str(sweep_dir)) == []


def test_load_fleet_rejects_wrong_schema(tmp_path):
    bad = tmp_path / "FLEET_bad.json"
    bad.write_text(json.dumps({"schema": "lime-fleet-v0", "cells": []}))
    with pytest.raises(ValueError, match="lime-fleet-v1"):
        figures.load_fleet(str(bad))


def test_fleet_tail_latency_table_renders_quantiles(fleet_dir):
    f = figures.load_fleets(str(fleet_dir))[0]
    text = figures.fig_fleet_tail_latency(f)
    # Cluster roster: label, device count, bandwidth, planned latency.
    assert "orin2" in text and "| 83.0 |" in text and "| 100 |" in text
    # Tail table: TTFT p50/p99 and queueing p99 from the cell stats.
    assert "| 0.125 |" in text and "| 0.750 |" in text
    assert "| 0.456 |" in text
    # Mean TBT renders in milliseconds, makespan in seconds.
    assert "| 25.0 |" in text and "| 4.25 |" in text
    # Request-share table: jsq sent everything to orin2.
    assert "request share per cluster" in text
    rows = [l for l in text.splitlines() if l.startswith("| jsq |")]
    assert any("| 4 | 0 |" in r for r in rows)


def _affinity_doc():
    """A minimal lime-fleet-v2 artifact: the v1 fixture shape plus the
    affinity header and per-cell/per-shard reuse counters."""
    cell = _fleet_cell("plan", "sporadic", 4, [("orin2", 3), ("edge2", 1)])
    cell["affinity_hits"] = 2
    cell["reuse_tokens_saved"] = 96
    cell["spilled_sessions"] = 1
    for shard, hits in zip(cell["per_cluster"], (2, 0)):
        shard["affinity_hits"] = hits
        shard["reuse_tokens_saved"] = 48 * hits
    return {
        "affinity": {
            "budget_tokens": 4096,
            "page_tokens": 16,
            "sessions": 256,
            "spill_threshold_s": 0.5,
            "zipf_s": 1.1,
        },
        "cells": [cell],
        "clusters": [
            {"bw_mbps": 100.0, "devices": 2, "label": "orin2", "planned_ms_per_token": 83.0},
            {"bw_mbps": 150.0, "devices": 2, "label": "edge2", "planned_ms_per_token": 61.5},
        ],
        "count": 4,
        "lambda": 200.0,
        "model": "Qwen3-32B",
        "name": "fixture-fleet-affinity",
        "patterns": ["sporadic"],
        "routers": ["plan"],
        "schema": "lime-fleet-v2",
        "seed": 1,
        "steps": 4,
    }


def test_load_fleet_accepts_v2_and_renders_the_affinity_view(tmp_path):
    path = tmp_path / "FLEET_fixture-fleet-affinity.json"
    path.write_text(json.dumps(_affinity_doc()))
    f = figures.load_fleet(str(path))
    assert f.schema == "lime-fleet-v2"
    assert f.affinity["sessions"] == 256
    text = figures.render_fleet(f)
    assert "session affinity / KV reuse" in text
    # Header knobs plus the counter row: 2/4 hits is a 50% hit rate.
    assert "256 sessions" in text and "Zipf s=1.1" in text
    assert "| 2 | 50.0% | 96 | 1 |" in text


def test_load_fleet_enforces_the_downgrade_rule(tmp_path):
    # v2 tag without the affinity header — and the v1 tag with it — must
    # both be rejected, mirroring the Rust validator.
    doc = _affinity_doc()
    headerless = dict(doc)
    del headerless["affinity"]
    bad1 = tmp_path / "FLEET_headerless.json"
    bad1.write_text(json.dumps(headerless))
    with pytest.raises(ValueError, match="disagree"):
        figures.load_fleet(str(bad1))
    downgraded = dict(doc)
    downgraded["schema"] = "lime-fleet-v1"
    bad2 = tmp_path / "FLEET_downgraded.json"
    bad2.write_text(json.dumps(downgraded))
    with pytest.raises(ValueError, match="disagree"):
        figures.load_fleet(str(bad2))


def test_cli_renders_fleet_only_directory(fleet_dir, tmp_path, capsys):
    out = tmp_path / "figs"
    rc = figures.main([str(fleet_dir), "--out", str(out)])
    assert rc == 0
    assert (out / "fixture-fleet.md").exists()
    assert "wrote" in capsys.readouterr().out


def test_cli_renders_sweeps_and_fleets_together(sweep_dir, tmp_path, capsys):
    # Drop a fleet artifact into the sweep fixture directory: both
    # families render side by side.
    shard_counts = [("orin2", 2), ("edge2", 2)]
    doc = {
        "cells": [_fleet_cell("plan", "bursty", 4, shard_counts)],
        "clusters": [
            {"bw_mbps": 100.0, "devices": 2, "label": "orin2", "planned_ms_per_token": 83.0},
            {"bw_mbps": 150.0, "devices": 2, "label": "edge2", "planned_ms_per_token": 61.5},
        ],
        "count": 4,
        "lambda": 200.0,
        "model": "Qwen3-32B",
        "name": "side-fleet",
        "patterns": ["bursty"],
        "routers": ["plan"],
        "schema": "lime-fleet-v1",
        "seed": 1,
        "steps": 4,
    }
    (sweep_dir / "FLEET_side-fleet.json").write_text(json.dumps(doc))
    out = tmp_path / "figs"
    rc = figures.main([str(sweep_dir), "--out", str(out)])
    assert rc == 0
    assert (out / "testgrid.md").exists()
    assert (out / "side-fleet.md").exists()


def test_cli_errors_when_no_artifacts(tmp_path):
    empty = tmp_path / "nothing"
    empty.mkdir()
    with pytest.raises(FileNotFoundError, match="SWEEP_.*FLEET_"):
        figures.main([str(empty)])
