"""Consumer for the Rust sweep artifacts (schemas ``lime-sweep-v2``
through ``lime-sweep-v7``; see ``docs/SWEEPS.md`` for the schema
reference).

``lime experiments --id sweep`` writes one ``SWEEP_<grid>.json`` per
scenario matrix (lowmem settings + cluster-size subsets). This module
renders those artifacts into the paper's figure layouts:

* :func:`fig_latency_vs_bandwidth` — methods × bandwidth per pattern
  (Figs 12–17 layout), from the baseline axis point;
* :func:`fig_seg_curve` — LIME latency vs ``#Seg`` (Figs 7–8 layout),
  from the ``#Seg``-override axis;
* :func:`fig_memory_fluctuation` — LIME latency + §IV-D adaptation
  counters per pressure scenario (the Table-V-flavoured view of the
  online planner / KV transfer machinery); v3 artifacts add the per-cell
  bandwidth-stall counter inflated by joint bandwidth+memory scripts;
* :func:`fig_queueing_delay` — request-level serving metrics from the
  v4 arrival-process axis: per-stream-cell mean/max queueing delay,
  TTFT, and time-between-tokens (the §V-A continuous-serving view);
* :func:`fig_recovery_latency` — the v5 device-churn axis: per churn
  script and method, latency plus the re-plans fired, KV bytes
  migrated, and recovery steps per Down event (``—`` when the run
  ended degraded) — the LIME-vs-EdgeShard robustness comparison;
* :func:`fig_batching` — the v6 batching-policy axis: FIFO vs
  step-level continuous admission per (bandwidth, pattern) stream
  column — mean/max queueing delay, TTFT, TBT plus the paged-KV
  counters (pages allocated / spilled, peak fragmentation) the
  continuous cells carry (see ``docs/SERVING.md``);
* :func:`fig_length_mix` — the v7 workload-mix axis: fixed-length vs
  mixed-length request streams on the same (batching, column) point —
  the per-request ``prompt_len``/``steps`` spread each cell served
  alongside its queueing/TTFT/TBT metrics, the serving-side cost of
  ragged batches;
* :func:`speedup_summary` — LIME's speedup over the best completing
  baseline per column (the paper's headline numbers).

``lime fleet`` writes one ``FLEET_<name>.json`` (schema
``lime-fleet-v1``, or ``lime-fleet-v2`` when sticky-session affinity
routing is on): N heterogeneous clusters behind a global admission
router, with streaming P²/reservoir tail-latency quantiles per
(router, pattern) cell. :func:`fig_fleet_tail_latency` renders the
p50/p95/p99 TTFT / queueing-delay table by router policy and arrival
pattern, plus the per-cluster request share;
:func:`fig_fleet_affinity` adds the v2 view — per-cell affinity hits,
hit rate, KV tokens saved by prefix reuse, and session spills.

Everything is stdlib-only and renders Markdown tables; ``--plot`` adds
PNGs when matplotlib is importable (it is optional on purpose — CI and
edge boxes don't have it).

Usage::

    python -m sweeps.figures path/to/sweeps [--out figs] [--plot]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import dataclass
from typing import Any

SCHEMAS = (
    "lime-sweep-v2",
    "lime-sweep-v3",
    "lime-sweep-v4",
    "lime-sweep-v5",
    "lime-sweep-v6",
    "lime-sweep-v7",
)
FLEET_SCHEMAS = ("lime-fleet-v1", "lime-fleet-v2")
FLEET_SCHEMA = FLEET_SCHEMAS[0]  # kept for callers pinned to the v1 tag


@dataclass
class Grid:
    """One parsed sweep artifact."""

    grid: str
    model: str
    tokens: int
    axes: dict[str, Any]
    cells: list[dict[str, Any]]
    path: str = ""

    @property
    def baseline_mem(self) -> str:
        return self.axes["mem_scenarios"][0]["label"]

    @property
    def baseline_churn(self) -> str:
        """Label of the event-free churn script — v5 pins it at index 0;
        pre-v5 artifacts carry no churn axis and every cell is fault-free."""
        scripts = self.axes.get("churn_scripts")
        return scripts[0]["label"] if scripts else "none"

    def at_baseline_churn(self, cell: dict[str, Any]) -> bool:
        return cell.get("churn", self.baseline_churn) == self.baseline_churn

    @property
    def baseline_batching(self) -> str:
        """Label of the FIFO batching policy — v6 pins it at index 0;
        pre-v6 artifacts carry no batching axis and every cell is FIFO."""
        axis = self.axes.get("batching")
        return axis[0]["label"] if axis else "fifo"

    def at_baseline_batching(self, cell: dict[str, Any]) -> bool:
        return cell.get("batching", self.baseline_batching) == self.baseline_batching

    def batching_labels(self) -> list[str]:
        """All batching-policy labels (v6; ``["fifo"]`` pre-v6)."""
        axis = self.axes.get("batching")
        return [b["label"] for b in axis] if axis else ["fifo"]

    @property
    def baseline_workload(self) -> str:
        """Label of the fixed-length workload — v7 pins it at index 0;
        pre-v7 artifacts carry no workload axis and every cell serves
        the global fixed-length stream."""
        axis = self.axes.get("workloads")
        return axis[0]["label"] if axis else "fixed"

    def at_baseline_workload(self, cell: dict[str, Any]) -> bool:
        return cell.get("workload", self.baseline_workload) == self.baseline_workload

    def workload_labels(self) -> list[str]:
        """All workload-distribution labels (v7; ``["fixed"]`` pre-v7)."""
        axis = self.axes.get("workloads")
        return [w["label"] for w in axis] if axis else ["fixed"]

    def baseline_cells(self) -> list[dict[str, Any]]:
        """Cells at the baseline axis point (auto seg, no pressure,
        single-run arrival, no churn — pre-v4/v5 artifacts carry no
        arrival/churn keys)."""
        return [
            c
            for c in self.cells
            if c["seg"] == "auto"
            and c["mem"] == self.baseline_mem
            and c.get("arrival", "single") == "single"
            and self.at_baseline_churn(c)
        ]

    def lime_cells(self) -> list[dict[str, Any]]:
        return [c for c in self.cells if c["method"] == "lime"]

    def stream_cells(self) -> list[dict[str, Any]]:
        """v4 continuous-serving cells (non-null ``requests`` arrays)."""
        return [c for c in self.cells if c.get("requests")]

    def churn_labels(self) -> list[str]:
        """Labels of the event-carrying churn scripts (v5; empty pre-v5)."""
        return [
            s["label"]
            for s in self.axes.get("churn_scripts", [])
            if s.get("events")
        ]


def load_grid(path: str) -> Grid:
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("schema") not in SCHEMAS:
        raise ValueError(
            f"{path}: expected schema in {SCHEMAS}, got {doc.get('schema')!r}"
        )
    for key in ("grid", "model", "tokens", "axes", "cells"):
        if key not in doc:
            raise ValueError(f"{path}: missing '{key}'")
    return Grid(
        grid=doc["grid"],
        model=doc["model"],
        tokens=doc["tokens"],
        axes=doc["axes"],
        cells=doc["cells"],
        path=path,
    )


def load_sweeps(directory: str) -> list[Grid]:
    """Load every ``SWEEP_*.json`` artifact in ``directory``, sorted by
    name (other JSON files — bench output, candidate baselines — are
    ignored, matching ``lime sweep-check``)."""
    names = sorted(
        n
        for n in os.listdir(directory)
        if n.startswith("SWEEP_") and n.endswith(".json")
    )
    if not names:
        raise FileNotFoundError(f"no SWEEP_*.json artifacts in {directory}")
    return [load_grid(os.path.join(directory, n)) for n in names]


@dataclass
class Fleet:
    """One parsed ``lime-fleet-v1``/``lime-fleet-v2`` artifact."""

    name: str
    model: str
    count: int
    steps: int
    clusters: list[dict[str, Any]]
    routers: list[str]
    patterns: list[str]
    cells: list[dict[str, Any]]
    schema: str = FLEET_SCHEMA
    affinity: dict[str, Any] | None = None
    path: str = ""


def load_fleet(path: str) -> Fleet:
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("schema") not in FLEET_SCHEMAS:
        raise ValueError(
            f"{path}: expected schema in {FLEET_SCHEMAS!r}, got {doc.get('schema')!r}"
        )
    for key in ("name", "model", "count", "steps", "clusters", "routers", "patterns", "cells"):
        if key not in doc:
            raise ValueError(f"{path}: missing '{key}'")
    # The singleton-downgrade rule: the affinity header and the v2 tag
    # imply each other (the Rust validator enforces the same invariant).
    if (doc["schema"] == "lime-fleet-v2") != ("affinity" in doc):
        raise ValueError(
            f"{path}: schema {doc['schema']!r} and affinity header presence disagree"
        )
    return Fleet(
        name=doc["name"],
        model=doc["model"],
        count=doc["count"],
        steps=doc["steps"],
        clusters=doc["clusters"],
        routers=doc["routers"],
        patterns=doc["patterns"],
        cells=doc["cells"],
        schema=doc["schema"],
        affinity=doc.get("affinity"),
        path=path,
    )


def load_fleets(directory: str) -> list[Fleet]:
    """Load every ``FLEET_*.json`` artifact in ``directory``, sorted by
    name. Unlike :func:`load_sweeps` an empty result is fine — fleets are
    an optional second artifact family."""
    names = sorted(
        n
        for n in os.listdir(directory)
        if n.startswith("FLEET_") and n.endswith(".json")
    )
    return [load_fleet(os.path.join(directory, n)) for n in names]


def _fmt_cell(cell: dict[str, Any]) -> str:
    if cell.get("oom"):
        return "OOM"
    if cell.get("oot"):
        return "OOT"
    return f"{cell['ms_per_token']:.1f}"


def _fmt_counter(cell: dict[str, Any], key: str) -> str:
    """An adaptation counter as table text: ``-`` when the key is absent
    (v2 artifacts without ``bw_stalls``) or null (OOM cells)."""
    value = cell.get(key)
    return "-" if value is None else str(value)


def _md_table(header: list[str], rows: list[list[str]]) -> str:
    lines = [
        "| " + " | ".join(header) + " |",
        "|" + "|".join("---" for _ in header) + "|",
    ]
    lines += ["| " + " | ".join(row) + " |" for row in rows]
    return "\n".join(lines)


# --------------------------------------------------------------- figures


def fig_latency_vs_bandwidth(grid: Grid) -> str:
    """Figs 12–17 layout: ms/token per method across the bandwidth axis,
    one table per request pattern, from the baseline axis point."""
    out = [f"## {grid.grid} — latency vs bandwidth ({grid.model}, {grid.tokens} tok)"]
    base = grid.baseline_cells()
    bandwidths = grid.axes["bandwidths_mbps"]
    for pattern in grid.axes["patterns"]:
        rows = []
        for method in grid.axes["methods"]:
            cells = {
                c["bandwidth_mbps"]: c
                for c in base
                if c["method"] == method and c["pattern"] == pattern
            }
            name = next(
                (c["method_name"] for c in cells.values()), method
            )
            rows.append(
                [name]
                + [
                    _fmt_cell(cells[bw]) if bw in cells else "-"
                    for bw in bandwidths
                ]
            )
        header = ["method (ms/token)"] + [f"{bw:g} Mbps" for bw in bandwidths]
        out.append(f"### pattern: {pattern}")
        out.append(_md_table(header, rows))
    return "\n\n".join(out)


def fig_seg_curve(grid: Grid) -> str:
    """Figs 7–8 layout: LIME ms/token against the ``#Seg``-override axis,
    one row per (bandwidth, pattern) column. The ``auto`` column reports
    the scheduler's own pick as ``ms (seg=k)``."""
    out = [f"## {grid.grid} — LIME latency vs #Seg override"]
    segs = grid.axes["segs"]
    rows = []
    for c_bw in grid.axes["bandwidths_mbps"]:
        for pattern in grid.axes["patterns"]:
            cells = {
                c["seg"]: c
                for c in grid.lime_cells()
                if c["bandwidth_mbps"] == c_bw
                and c["pattern"] == pattern
                and c["mem"] == grid.baseline_mem
                and c.get("arrival", "single") == "single"
                and grid.at_baseline_churn(c)
            }
            row = [f"{c_bw:g} Mbps / {pattern}"]
            for seg in segs:
                if seg not in cells:
                    row.append("-")
                elif seg == "auto" and cells[seg].get("planned_seg") is not None:
                    row.append(
                        f"{_fmt_cell(cells[seg])} (seg={cells[seg]['planned_seg']})"
                    )
                else:
                    row.append(_fmt_cell(cells[seg]))
            rows.append(row)
    header = ["column"] + [f"#Seg={s}" if s != "auto" else "auto" for s in segs]
    out.append(_md_table(header, rows))
    return "\n\n".join(out)


def fig_memory_fluctuation(grid: Grid) -> str:
    """§IV-D view: LIME under each pressure scenario — latency plus the
    online-adaptation counters that the scenario axis exists to surface
    (plans fired, KV tokens shipped, emergency spill steps, and — on
    ``lime-sweep-v3`` artifacts — link stalls inflated by scripted
    bandwidth sags)."""
    out = [f"## {grid.grid} — LIME under memory/bandwidth fluctuation"]
    has_stalls = any("bw_stalls" in c for c in grid.cells)
    rows = []
    for scenario in grid.axes["mem_scenarios"]:
        label = scenario["label"]
        for c in grid.lime_cells():
            if (
                c["mem"] != label
                or c["seg"] != "auto"
                or c.get("arrival", "single") != "single"
                or not grid.at_baseline_churn(c)
            ):
                continue
            row = [
                label,
                f"{c['bandwidth_mbps']:g} Mbps / {c['pattern']}",
                _fmt_cell(c),
                _fmt_counter(c, "online_plans_fired"),
                _fmt_counter(c, "kv_tokens_transferred"),
                _fmt_counter(c, "emergency_steps"),
            ]
            if has_stalls:
                row.append(_fmt_counter(c, "bw_stalls"))
            rows.append(row)
    header = [
        "scenario",
        "column",
        "ms/token",
        "plans fired",
        "KV tokens shipped",
        "emergency steps",
    ]
    if has_stalls:
        header.append("link stalls")
    out.append(_md_table(header, rows))
    return "\n\n".join(out)


def fig_queueing_delay(grid: Grid) -> str:
    """The v4 continuous-serving view: per-request queueing delay, TTFT
    and time-between-tokens summaries for every completed stream cell
    (auto seg, baseline pressure, FIFO batching, fixed-length workload —
    the v6 continuous twins get their own :func:`fig_batching`
    comparison and the v7 mixed-length twins their own
    :func:`fig_length_mix`), one row per (arrival, column). Bursty streams should show the queueing the
    sporadic pattern avoids — the serving-side shape of the paper's
    §V-A comparison."""
    out = [f"## {grid.grid} — request-level serving metrics (stream cells)"]

    def mean(vals: list[float]) -> float:
        return sum(vals) / len(vals) if vals else 0.0

    rows = []
    for c in grid.stream_cells():
        if (
            c["method"] != "lime"
            or c["seg"] != "auto"
            or c["mem"] != grid.baseline_mem
            or not grid.at_baseline_churn(c)
            or not grid.at_baseline_batching(c)
            or not grid.at_baseline_workload(c)
        ):
            continue
        req = c["requests"]
        qd, ttft, tbt = req["queueing_delay_s"], req["ttft_s"], req["tbt_s"]
        rows.append(
            [
                c.get("arrival", "?"),
                f"{c['bandwidth_mbps']:g} Mbps / {c['pattern']}",
                str(len(qd)),
                f"{mean(qd):.3f}",
                f"{max(qd):.3f}" if qd else "-",
                f"{mean(ttft):.3f}",
                f"{mean(tbt) * 1e3:.1f}",
            ]
        )
    header = [
        "arrival",
        "column",
        "requests",
        "mean qd (s)",
        "max qd (s)",
        "mean TTFT (s)",
        "mean TBT (ms)",
    ]
    out.append(_md_table(header, rows))
    return "\n\n".join(out)


def fig_batching(grid: Grid) -> str:
    """The v6 batching-policy view: FIFO vs step-level continuous
    admission on the same stream columns (LIME, auto seg, baseline
    pressure/churn/workload — mixed-length twins get their own
    :func:`fig_length_mix` view). One row per (batching policy, column) — the serving
    metrics FIFO rows share with :func:`fig_queueing_delay`, plus the
    paged-KV counters (pages allocated / spilled and peak
    fragmentation; exactly zero on FIFO rows, which never touch the
    page pool — ``-`` only on OOM). Continuous rows should show the lower mean
    queueing delay the admission overlap exists for — the sweep's
    acceptance gate pins that strictly on the bursty columns (see
    ``docs/SERVING.md``)."""
    out = [f"## {grid.grid} — FIFO vs continuous batching (stream cells)"]

    def mean(vals: list[float]) -> float:
        return sum(vals) / len(vals) if vals else 0.0

    def frag(cell: dict[str, Any]) -> str:
        value = cell.get("fragmentation")
        return "-" if value is None else f"{value:.3f}"

    rows = []
    for batching in grid.batching_labels():
        for c in grid.stream_cells():
            if (
                c["method"] != "lime"
                or c["seg"] != "auto"
                or c["mem"] != grid.baseline_mem
                or not grid.at_baseline_churn(c)
                or not grid.at_baseline_workload(c)
                or c.get("batching", grid.baseline_batching) != batching
            ):
                continue
            req = c["requests"]
            qd, ttft, tbt = req["queueing_delay_s"], req["ttft_s"], req["tbt_s"]
            rows.append(
                [
                    batching,
                    f"{c['bandwidth_mbps']:g} Mbps / {c['pattern']}",
                    str(len(qd)),
                    f"{mean(qd):.3f}",
                    f"{max(qd):.3f}" if qd else "-",
                    f"{mean(ttft):.3f}",
                    f"{mean(tbt) * 1e3:.1f}",
                    _fmt_counter(c, "kv_pages_allocated"),
                    _fmt_counter(c, "kv_pages_spilled"),
                    frag(c),
                ]
            )
    header = [
        "batching",
        "column",
        "requests",
        "mean qd (s)",
        "max qd (s)",
        "mean TTFT (s)",
        "mean TBT (ms)",
        "KV pages",
        "pages spilled",
        "peak frag",
    ]
    out.append(_md_table(header, rows))
    return "\n\n".join(out)


def fig_length_mix(grid: Grid) -> str:
    """The v7 workload-mix view: the same stream columns served under
    each request-length distribution (LIME, auto seg, baseline
    pressure/churn), one row per (workload, batching, column). The
    per-request ``prompt_len``/``steps`` arrays the v7 cells carry make
    the spread visible next to the serving metrics: the fixed rows show
    degenerate ``min=max`` spreads, the bimodal rows the short-chat /
    long-context mix whose stragglers continuous admission exists to
    absorb (see ``docs/SERVING.md``)."""
    out = [f"## {grid.grid} — fixed vs mixed-length workloads (stream cells)"]

    def mean(vals: list[float]) -> float:
        return sum(vals) / len(vals) if vals else 0.0

    def spread(vals: list[int]) -> str:
        if not vals:
            return "-"
        return f"{min(vals)}/{mean(vals):.0f}/{max(vals)}"

    rows = []
    for workload in grid.workload_labels():
        for batching in grid.batching_labels():
            for c in grid.stream_cells():
                if (
                    c["method"] != "lime"
                    or c["seg"] != "auto"
                    or c["mem"] != grid.baseline_mem
                    or not grid.at_baseline_churn(c)
                    or c.get("workload", grid.baseline_workload) != workload
                    or c.get("batching", grid.baseline_batching) != batching
                ):
                    continue
                req = c["requests"]
                qd, ttft, tbt = req["queueing_delay_s"], req["ttft_s"], req["tbt_s"]
                # Pre-v7 artifacts carry no length arrays; the global
                # fixed-length knob applies and the spread shows "-".
                prompts = req.get("prompt_len", [])
                steps = req.get("steps", [])
                rows.append(
                    [
                        workload,
                        batching,
                        f"{c['bandwidth_mbps']:g} Mbps / {c['pattern']}",
                        str(len(qd)),
                        spread(prompts),
                        spread(steps),
                        f"{mean(qd):.3f}",
                        f"{mean(ttft):.3f}",
                        f"{mean(tbt) * 1e3:.1f}",
                    ]
                )
    header = [
        "workload",
        "batching",
        "column",
        "requests",
        "prompt min/mean/max",
        "steps min/mean/max",
        "mean qd (s)",
        "mean TTFT (s)",
        "mean TBT (ms)",
    ]
    out.append(_md_table(header, rows))
    return "\n\n".join(out)


def fig_recovery_latency(grid: Grid) -> str:
    """The v5 device-churn view: for each event-carrying churn script,
    every method that ran under it (LIME's adaptive family plus the
    churn-capable EdgeShard baseline) at the baseline axis point — its
    degraded-vs-baseline latency, the re-plans the fault fired, the KV
    bytes migrated off the departing device (Eq. 8 volume model), and the
    recovery steps per Down event, ``—`` when the run ended degraded.
    This is the robustness comparison the churn axis exists for: LIME
    re-plans around the fault while static partitions ride it out."""
    out = [f"## {grid.grid} — recovery latency under device churn"]

    def recovery(cell: dict[str, Any]) -> str:
        steps = cell.get("recovery_steps")
        if not steps:
            return "-"
        return ", ".join("—" if s is None else str(s) for s in steps)

    def at_point(method: str, churn: str) -> list[dict[str, Any]]:
        return [
            c
            for c in grid.cells
            if c["method"] == method
            and c.get("churn", grid.baseline_churn) == churn
            and c["seg"] == "auto"
            and c["mem"] == grid.baseline_mem
            and c.get("arrival", "single") == "single"
        ]

    rows = []
    for churn in grid.churn_labels():
        for method in grid.axes["methods"]:
            # Rigid baselines are pinned to the no-churn point, so this
            # is empty for them and they drop out of the table.
            for cell in at_point(method, churn):
                base = next(
                    (
                        b
                        for b in at_point(method, grid.baseline_churn)
                        if b["bandwidth_mbps"] == cell["bandwidth_mbps"]
                        and b["pattern"] == cell["pattern"]
                    ),
                    None,
                )
                rows.append(
                    [
                        churn,
                        cell["method_name"],
                        f"{cell['bandwidth_mbps']:g} Mbps / {cell['pattern']}",
                        _fmt_cell(base) if base else "-",
                        _fmt_cell(cell),
                        _fmt_counter(cell, "replans_fired"),
                        _fmt_counter(cell, "kv_migrated_bytes"),
                        recovery(cell),
                    ]
                )
    header = [
        "churn script",
        "method",
        "column",
        "baseline ms/token",
        "churned ms/token",
        "re-plans",
        "KV migrated (B)",
        "recovery (steps per Down)",
    ]
    out.append(_md_table(header, rows))
    return "\n\n".join(out)


def speedup_summary(grid: Grid) -> str:
    """LIME's speedup over the best completing baseline per column — the
    shape of the paper's 1.7x/3.7x headline claims."""
    out = [f"## {grid.grid} — LIME speedup over best completing baseline"]
    rows = []
    base = grid.baseline_cells()
    for bw in grid.axes["bandwidths_mbps"]:
        for pattern in grid.axes["patterns"]:
            col = [
                c
                for c in base
                if c["bandwidth_mbps"] == bw and c["pattern"] == pattern
            ]
            lime = next((c for c in col if c["method"] == "lime"), None)
            rivals = [
                c
                for c in col
                if c["method"] != "lime" and not c.get("oom") and not c.get("oot")
            ]
            # OOM/OOT LIME cells are failures on the Rust side — exclude
            # them exactly as OOM/OOT rivals are excluded above.
            if not lime or lime.get("oom") or lime.get("oot") or not rivals:
                continue
            best = min(rivals, key=lambda c: c["ms_per_token"])
            rows.append(
                [
                    f"{bw:g} Mbps / {pattern}",
                    best["method_name"],
                    f"{best['ms_per_token'] / lime['ms_per_token']:.2f}x",
                ]
            )
    out.append(_md_table(["column", "best baseline", "LIME speedup"], rows))
    return "\n\n".join(out)


def fig_fleet_tail_latency(fleet: Fleet) -> str:
    """The ``lime-fleet-v1`` view: streaming tail-latency quantiles per
    (router policy × arrival pattern) cell — TTFT mean/p50/p95/p99,
    queueing-delay p99, mean TBT and makespan — preceded by the fleet's
    cluster roster and followed by how each router split the stream
    across clusters."""
    out = [
        f"## {fleet.name} — fleet tail latency "
        f"({fleet.model}, {fleet.count} requests x {fleet.steps} tok)"
    ]

    cluster_rows = [
        [
            c["label"],
            str(c["devices"]),
            f"{c['bw_mbps']:g}",
            f"{c['planned_ms_per_token']:.1f}",
        ]
        for c in fleet.clusters
    ]
    out.append("### clusters")
    out.append(
        _md_table(
            ["cluster", "devices", "bw (Mbps)", "planned ms/token"],
            cluster_rows,
        )
    )

    rows = []
    for cell in fleet.cells:
        ttft, qd, tbt = cell["ttft_s"], cell["queueing_delay_s"], cell["tbt_s"]
        rows.append(
            [
                cell["router"],
                cell["pattern"],
                str(cell["count"]),
                f"{ttft['mean']:.3f}",
                f"{ttft['p50']:.3f}",
                f"{ttft['p95']:.3f}",
                f"{ttft['p99']:.3f}",
                f"{qd['p99']:.3f}",
                f"{tbt['mean'] * 1e3:.1f}",
                f"{cell['makespan_s']:.2f}",
            ]
        )
    header = [
        "router",
        "pattern",
        "requests",
        "TTFT mean (s)",
        "TTFT p50",
        "TTFT p95",
        "TTFT p99",
        "qd p99 (s)",
        "mean TBT (ms)",
        "makespan (s)",
    ]
    out.append("### tail latency by router x pattern")
    out.append(_md_table(header, rows))

    share_rows = [
        [cell["router"], cell["pattern"]]
        + [str(shard["count"]) for shard in cell["per_cluster"]]
        for cell in fleet.cells
    ]
    out.append("### request share per cluster")
    out.append(
        _md_table(
            ["router", "pattern"] + [c["label"] for c in fleet.clusters],
            share_rows,
        )
    )
    return "\n\n".join(out)


def fig_fleet_affinity(fleet: Fleet) -> str:
    """The ``lime-fleet-v2`` view: what sticky-session routing bought per
    (router × pattern) cell — affinity hits and hit rate (requests whose
    session returned to its resident cluster with KV still warm), decode
    tokens of prefill skipped via prefix reuse, and sessions spilled off
    their resident cluster by the backlog threshold — headed by the
    affinity knobs the artifact was generated with."""
    aff = fleet.affinity
    assert aff is not None, "fig_fleet_affinity needs a lime-fleet-v2 artifact"
    out = [
        f"## {fleet.name} — session affinity / KV reuse",
        f"{aff['sessions']} sessions, Zipf s={aff['zipf_s']:g}, "
        f"spill threshold {aff['spill_threshold_s']:g} s, "
        f"{aff['page_tokens']}-token pages, "
        f"budget {aff['budget_tokens']} tokens/cluster",
    ]
    rows = []
    for cell in fleet.cells:
        hits = cell["affinity_hits"]
        rows.append(
            [
                cell["router"],
                cell["pattern"],
                str(cell["count"]),
                str(hits),
                f"{hits / cell['count'] * 100.0:.1f}%",
                _fmt_counter(cell, "reuse_tokens_saved"),
                _fmt_counter(cell, "spilled_sessions"),
            ]
        )
    out.append(
        _md_table(
            [
                "router",
                "pattern",
                "requests",
                "affinity hits",
                "hit rate",
                "reuse tokens saved",
                "spilled sessions",
            ],
            rows,
        )
    )
    return "\n\n".join(out)


def render_fleet(fleet: Fleet) -> str:
    parts = [fig_fleet_tail_latency(fleet)]
    if fleet.affinity is not None:
        parts.append(fig_fleet_affinity(fleet))
    return "\n\n".join(parts)


def render_grid(grid: Grid) -> str:
    parts = [
        fig_latency_vs_bandwidth(grid),
        fig_seg_curve(grid),
        fig_memory_fluctuation(grid),
    ]
    if grid.stream_cells():
        parts.append(fig_queueing_delay(grid))
    if len(grid.batching_labels()) > 1:
        parts.append(fig_batching(grid))
    if len(grid.workload_labels()) > 1:
        parts.append(fig_length_mix(grid))
    if grid.churn_labels():
        parts.append(fig_recovery_latency(grid))
    parts.append(speedup_summary(grid))
    return "\n\n".join(parts)


# ------------------------------------------------------------ optional PNG


def plot_grid(grid: Grid, out_dir: str) -> list[str]:
    """Write PNG panels with matplotlib; a no-op (with a warning) when
    matplotlib is unavailable. Returns the paths written."""
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        print("matplotlib not available; skipping PNG output", file=sys.stderr)
        return []
    written = []
    base = grid.baseline_cells()
    for pattern in grid.axes["patterns"]:
        fig, ax = plt.subplots(figsize=(6, 4))
        for method in grid.axes["methods"]:
            pts = sorted(
                (c["bandwidth_mbps"], c["ms_per_token"])
                for c in base
                if c["method"] == method
                and c["pattern"] == pattern
                and not c.get("oom")
            )
            if pts:
                ax.plot(*zip(*pts), marker="o", label=method)
        ax.set_xlabel("bandwidth (Mbps)")
        ax.set_ylabel("ms / token")
        ax.set_yscale("log")
        ax.set_title(f"{grid.grid} / {pattern} ({grid.model})")
        ax.legend(fontsize=7)
        path = os.path.join(out_dir, f"{grid.grid}_{pattern}.png")
        fig.savefig(path, dpi=150, bbox_inches="tight")
        plt.close(fig)
        written.append(path)
    return written


# ------------------------------------------------------------------- CLI


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("sweep_dir", help="directory of SWEEP_*.json / FLEET_*.json artifacts")
    ap.add_argument("--out", default="", help="write per-grid .md (and PNGs) here")
    ap.add_argument("--plot", action="store_true", help="also emit PNGs (needs matplotlib)")
    args = ap.parse_args(argv)

    try:
        grids = load_sweeps(args.sweep_dir)
    except FileNotFoundError:
        grids = []
    fleets = load_fleets(args.sweep_dir)
    if not grids and not fleets:
        raise FileNotFoundError(
            f"no SWEEP_*.json or FLEET_*.json artifacts in {args.sweep_dir}"
        )
    if args.out:
        os.makedirs(args.out, exist_ok=True)

    def emit(text: str, stem: str) -> None:
        if args.out:
            path = os.path.join(args.out, f"{stem}.md")
            with open(path, "w", encoding="utf-8") as f:
                f.write(text + "\n")
            print(f"wrote {path}")
        else:
            print(text)
            print()

    for grid in grids:
        emit(render_grid(grid), grid.grid)
        if args.out and args.plot:
            for png in plot_grid(grid, args.out):
                print(f"wrote {png}")
    for fleet in fleets:
        emit(render_fleet(fleet), fleet.name)
    return 0


if __name__ == "__main__":
    sys.exit(main())
