"""AOT compile path: lower every TinyLM entry point to HLO text + export weights.

Run once at build time (`make artifacts`); Python never touches the request
path afterwards. Interchange format is **HLO text**, not a serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids which
xla_extension 0.5.1 (the version behind the published `xla` 0.1.6 crate)
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):
  <entry>.hlo.txt        one per entry point
  weights/<tensor>.bin   raw little-endian f32 blobs
  manifest.json          model config + per-artifact parameter order +
                         tensor inventory (written LAST: build sentinel)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .config import CFG


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_specs():
    """Every AOT entry point: name -> (fn, [(param_name, ShapeDtypeStruct)]).

    Param order here IS the PJRT parameter order the Rust runtime must feed.
    """
    cfg = CFG
    H, P, S = cfg.hidden, cfg.prefill_len, cfg.max_seq
    KVH, hd, F, V = cfg.kv_heads, cfg.head_dim, cfg.ffn, cfg.vocab
    nH = cfg.heads

    x1 = ("x", _sds((1, 1, H)))
    xp = ("x", _sds((1, P, H)))
    kc = ("k_cache", _sds((1, S, KVH, hd)))
    vc = ("v_cache", _sds((1, S, KVH, hd)))
    pos = ("pos", _sds((), jnp.int32))
    attn_w = [
        ("ln1", _sds((H,))),
        ("wq", _sds((H, nH * hd))),
        ("wk", _sds((H, KVH * hd))),
        ("wv", _sds((H, KVH * hd))),
        ("wo", _sds((nH * hd, H))),
    ]
    mlp_w = [
        ("ln2", _sds((H,))),
        ("w_gate", _sds((H, F))),
        ("w_up", _sds((H, F))),
        ("w_down", _sds((F, H))),
    ]

    return {
        "embed_prefill": (
            model.embed_prefill,
            [("tokens", _sds((1, P), jnp.int32)), ("table", _sds((V, H)))],
        ),
        "embed_decode": (
            model.embed_decode,
            [("tokens", _sds((1, 1), jnp.int32)), ("table", _sds((V, H)))],
        ),
        "layer_prefill": (model.layer_prefill, [xp] + attn_w + mlp_w),
        "layer_decode": (
            model.layer_decode,
            [x1, kc, vc, pos] + attn_w + mlp_w,
        ),
        "mha_decode": (model.mha_decode, [x1, kc, vc, pos] + attn_w),
        "mlp_decode": (model.mlp_decode, [x1] + mlp_w),
        "lm_head": (
            model.lm_head,
            [x1, ("ln_f", _sds((H,))), ("w_out", _sds((H, V)))],
        ),
    }


def export_weights(out_dir, seed=0):
    """Write every weight tensor as raw LE f32 and return the inventory."""
    weights = model.make_weights(seed)
    wdir = os.path.join(out_dir, "weights")
    os.makedirs(wdir, exist_ok=True)
    inventory = {}

    def dump(name, arr):
        arr = np.asarray(arr, dtype=np.float32)
        path = os.path.join("weights", f"{name}.bin")
        arr.tofile(os.path.join(out_dir, path))
        inventory[name] = {"shape": list(arr.shape), "file": path}

    dump("embed", weights["embed"])
    dump("ln_f", weights["ln_f"])
    dump("lm_head", weights["lm_head"])
    for li in range(CFG.layers):
        for wname, arr in zip(model.LAYER_WEIGHT_NAMES, weights[f"layer{li}"]):
            dump(f"layer{li}.{wname}", arr)
    return inventory


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    artifacts = {}
    for name, (fn, params) in entry_specs().items():
        lowered = jax.jit(fn).lower(*[sds for _, sds in params])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        artifacts[name] = {
            "file": fname,
            "params": [
                {
                    "name": pname,
                    "shape": list(sds.shape),
                    "dtype": str(sds.dtype),
                }
                for pname, sds in params
            ],
        }
        print(f"lowered {name:14s} -> {fname} ({len(text)} chars)")

    inventory = export_weights(args.out_dir, args.seed)

    manifest = {
        "model": {
            "name": "TinyLM",
            "vocab": CFG.vocab,
            "hidden": CFG.hidden,
            "layers": CFG.layers,
            "heads": CFG.heads,
            "kv_heads": CFG.kv_heads,
            "head_dim": CFG.head_dim,
            "ffn": CFG.ffn,
            "prefill_len": CFG.prefill_len,
            "max_seq": CFG.max_seq,
            "seed": args.seed,
        },
        "layer_weight_names": list(model.LAYER_WEIGHT_NAMES),
        "attn_weight_names": list(model.ATTN_WEIGHT_NAMES),
        "mlp_weight_names": list(model.MLP_WEIGHT_NAMES),
        "artifacts": artifacts,
        "tensors": inventory,
    }
    # Manifest is written last: it is the Makefile's build sentinel.
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(artifacts)} artifacts, "
          f"{len(inventory)} tensors")


if __name__ == "__main__":
    main()
