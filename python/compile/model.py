"""L2: TinyLM — the JAX compute graph AOT-lowered for the Rust coordinator.

Every entry point is a *pure function over explicit weight arguments*: the
Rust side owns weight residency (resident in "GPU" memory vs offloaded to
SSD) — that ownership is LIME's whole point — so weights arrive as PJRT
parameters on every call rather than being baked into the executable.

Entry points (each becomes one `artifacts/<name>.hlo.txt`):

  embed_prefill  tokens[1,P]                          -> x[1,P,H]
  embed_decode   tokens[1,1]                          -> x[1,1,H]
  layer_prefill  x[1,P,H], w...                       -> y, k[1,P,KVH,hd], v
  layer_decode   x[1,1,H], kc, vc, pos, w...          -> y, kc', vc'
  mha_decode     x[1,1,H], kc, vc, pos, w_attn...     -> y, kc', vc'
  mlp_decode     x[1,1,H], w_mlp...                   -> y
  lm_head        x[1,1,H], ln_f, w_out               -> logits[1,V]

`layer_decode == mlp_decode ∘ mha_decode` *exactly* — the fine-grained
(block-offload) execution path must be bit-identical to the fused layer, and
`python/tests/test_model.py` plus the Rust losslessness checker assert it.

Decode attention runs through the L1 Pallas kernel
(`kernels.gqa_decode_attention`); prefill attention is a one-shot jnp causal
pass (it runs once per request and is not the hot-spot).
"""

import jax
import jax.numpy as jnp

from .config import CFG
from .kernels import gqa_decode_attention

# --------------------------------------------------------------------------
# Primitives
# --------------------------------------------------------------------------


def rmsnorm(x, scale, eps=CFG.rms_eps):
    """RMSNorm over the last axis."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def _rope_angles(positions, head_dim, theta=CFG.rope_theta):
    """[T] positions -> (sin, cos) each [T, head_dim//2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, positions):
    """Rotary position embedding. x: [T, heads, head_dim], positions: [T]."""
    t, heads, head_dim = x.shape
    sin, cos = _rope_angles(positions, head_dim)
    sin = sin[:, None, :]  # [T, 1, half]
    cos = cos[:, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rot.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: (silu(x @ w_gate) * (x @ w_up)) @ w_down."""
    g = x @ w_gate
    return (jax.nn.silu(g) * (x @ w_up)) @ w_down


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------


def embed_prefill(tokens, table):
    """tokens [1, P] int32 -> hidden states [1, P, H]."""
    return (table[tokens],)


def embed_decode(tokens, table):
    """tokens [1, 1] int32 -> hidden states [1, 1, H]."""
    return (table[tokens],)


def mha_decode(x, k_cache, v_cache, pos, ln1, wq, wk, wv, wo):
    """Attention block for one decode token (fine-grained offload unit).

    Args:
      x:        [1, 1, H] residual stream.
      k_cache:  [1, S, KVH, hd] padded key cache (valid slots: [0, pos)).
      v_cache:  [1, S, KVH, hd] padded value cache.
      pos:      scalar int32 — this token's position (== valid cache length).
      ln1, wq, wk, wv, wo: attention-block weights.

    Returns:
      (y [1,1,H], k_cache' with slot `pos` filled, v_cache' likewise).
    """
    cfg = CFG
    h = rmsnorm(x, ln1)[0]                                   # [1, H]
    q = (h @ wq).reshape(1, cfg.heads, cfg.head_dim)
    k_new = (h @ wk).reshape(1, cfg.kv_heads, cfg.head_dim)
    v_new = (h @ wv).reshape(1, cfg.kv_heads, cfg.head_dim)

    positions = jnp.asarray(pos, jnp.int32).reshape(1)
    q = apply_rope(q, positions)
    k_new = apply_rope(k_new, positions)

    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new[None, ...], (0, pos, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new[None, ...], (0, pos, 0, 0)
    )

    attn = gqa_decode_attention(q[0], k_cache[0], v_cache[0], pos + 1)
    y = x + (attn.reshape(1, cfg.hidden) @ wo)[None, ...]
    return y, k_cache, v_cache


def mlp_decode(x, ln2, w_gate, w_up, w_down):
    """MLP block for one decode token (fine-grained offload unit)."""
    return (x + swiglu(rmsnorm(x, ln2), w_gate, w_up, w_down),)


def layer_decode(
    x, k_cache, v_cache, pos, ln1, wq, wk, wv, wo, ln2, w_gate, w_up, w_down
):
    """Full decoder layer for one decode token = mlp_decode ∘ mha_decode."""
    y, k_cache, v_cache = mha_decode(x, k_cache, v_cache, pos, ln1, wq, wk, wv, wo)
    (y,) = mlp_decode(y, ln2, w_gate, w_up, w_down)
    return y, k_cache, v_cache


def layer_prefill(
    x, ln1, wq, wk, wv, wo, ln2, w_gate, w_up, w_down
):
    """Full decoder layer over the whole prompt (causal attention).

    Args:
      x: [1, P, H] hidden states.

    Returns:
      (y [1,P,H], k [1,P,KVH,hd], v [1,P,KVH,hd]) — the fresh KV entries; the
      Rust side writes them into its padded caches at slots [0, P).
    """
    cfg = CFG
    p = x.shape[1]
    h = rmsnorm(x, ln1)[0]                                   # [P, H]
    q = (h @ wq).reshape(p, cfg.heads, cfg.head_dim)
    k = (h @ wk).reshape(p, cfg.kv_heads, cfg.head_dim)
    v = (h @ wv).reshape(p, cfg.kv_heads, cfg.head_dim)

    positions = jnp.arange(p, dtype=jnp.int32)
    q = apply_rope(q, positions)
    k = apply_rope(k, positions)

    kv_index = jnp.arange(cfg.heads) // cfg.q_rep
    kf = k[:, kv_index, :]                                   # [P, nH, hd]
    vf = v[:, kv_index, :]
    scores = jnp.einsum("qhd,khd->hqk", q, kf) / jnp.sqrt(
        jnp.float32(cfg.head_dim)
    )
    causal = jnp.tril(jnp.ones((p, p), dtype=bool))
    scores = jnp.where(causal[None, :, :], scores, -jnp.float32(1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    attn = jnp.einsum("hqk,khd->qhd", probs, vf).reshape(p, cfg.hidden)

    y = x + (attn @ wo)[None, ...]
    (y,) = mlp_decode(y, ln2, w_gate, w_up, w_down)
    return y, k[None, ...], v[None, ...]


def lm_head(x, ln_f, w_out):
    """Final norm + output projection: [1,1,H] -> logits [1, V]."""
    h = rmsnorm(x, ln_f)[0]                                  # [1, H]
    return (h @ w_out,)


# --------------------------------------------------------------------------
# Whole-model reference (tests + losslessness oracle; never lowered)
# --------------------------------------------------------------------------


def forward_greedy(weights, prompt, steps):
    """Greedy generation with the un-split model; oracle for the Rust engine.

    Args:
      weights: dict from `make_weights`.
      prompt:  [P] int32 token ids.
      steps:   decode steps to run.

    Returns:
      list of generated token ids (greedy argmax), length `steps`.
    """
    cfg = CFG
    p = prompt.shape[0]
    x = embed_prefill(prompt[None, :], weights["embed"])[0]
    k_caches, v_caches = [], []
    for li in range(cfg.layers):
        w = weights[f"layer{li}"]
        x, k, v = layer_prefill(x, *w)
        kc = jnp.zeros((1, cfg.max_seq, cfg.kv_heads, cfg.head_dim), jnp.float32)
        vc = jnp.zeros_like(kc)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0))
        k_caches.append(kc)
        v_caches.append(vc)

    (logits,) = lm_head(x[:, -1:, :], weights["ln_f"], weights["lm_head"])
    out = []
    tok = jnp.argmax(logits[0]).astype(jnp.int32)
    for step in range(steps):
        out.append(int(tok))
        pos = p + step
        x = embed_decode(tok.reshape(1, 1), weights["embed"])[0]
        for li in range(cfg.layers):
            w = weights[f"layer{li}"]
            x, k_caches[li], v_caches[li] = layer_decode(
                x, k_caches[li], v_caches[li], jnp.int32(pos), *w
            )
        (logits,) = lm_head(x, weights["ln_f"], weights["lm_head"])
        tok = jnp.argmax(logits[0]).astype(jnp.int32)
    return out


def make_weights(seed=0):
    """Seeded synthetic TinyLM weights (no HF access; see DESIGN.md)."""
    cfg = CFG
    key = jax.random.PRNGKey(seed)

    def nxt():
        nonlocal key
        key, sub = jax.random.split(key)
        return sub

    def init(shape, scale=0.05):
        return (jax.random.normal(nxt(), shape, jnp.float32) * scale)

    weights = {
        "embed": init((cfg.vocab, cfg.hidden), 0.3),
        "ln_f": jnp.ones((cfg.hidden,), jnp.float32),
        "lm_head": init((cfg.hidden, cfg.vocab), 0.3),
    }
    for li in range(cfg.layers):
        weights[f"layer{li}"] = (
            jnp.ones((cfg.hidden,), jnp.float32),                 # ln1
            init((cfg.hidden, cfg.heads * cfg.head_dim)),         # wq
            init((cfg.hidden, cfg.kv_heads * cfg.head_dim)),      # wk
            init((cfg.hidden, cfg.kv_heads * cfg.head_dim)),      # wv
            init((cfg.heads * cfg.head_dim, cfg.hidden)),         # wo
            jnp.ones((cfg.hidden,), jnp.float32),                 # ln2
            init((cfg.hidden, cfg.ffn)),                          # w_gate
            init((cfg.hidden, cfg.ffn)),                          # w_up
            init((cfg.ffn, cfg.hidden)),                          # w_down
        )
    return weights


LAYER_WEIGHT_NAMES = (
    "ln1", "wq", "wk", "wv", "wo", "ln2", "w_gate", "w_up", "w_down"
)
ATTN_WEIGHT_NAMES = ("ln1", "wq", "wk", "wv", "wo")
MLP_WEIGHT_NAMES = ("ln2", "w_gate", "w_up", "w_down")
