"""L1 Pallas kernel: GQA decode attention (the serving hot-spot).

LIME's per-token decode step reads the whole KV cache once per layer — the
memory-bound hot-spot of edge serving. The paper's engine runs CUDA on Jetson
GPUs (shared-memory staging, warp reductions); per DESIGN.md
§Hardware-Adaptation we re-express the same insight for a TPU-style memory
hierarchy instead of porting warp idioms:

  * the grid iterates KV heads; each program owns one KV head's `q_rep`
    query heads — an MXU-shaped `[q_rep, head_dim] x [head_dim, chunk]`
    matmul per KV chunk;
  * the KV sequence is streamed through VMEM in `CHUNK`-sized tiles
    (BlockSpec stages the HBM→VMEM copy that the GPU code did with
    threadblock shared-memory tiles);
  * softmax is computed online (flash-attention style running max / sum) in
    f32 accumulators so one pass over the cache suffices;
  * inputs may be bf16; all accumulation is f32
    (`preferred_element_type=float32` targets the MXU's f32 accumulate).

Compiled with `interpret=True`: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO. The *structure* (tiling,
accumulator layout, VMEM budget) is what carries to real TPUs; see
EXPERIMENTS.md §Perf for the VMEM/MXU estimate.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# KV-sequence tile staged into VMEM per loop iteration. With head_dim=16 and
# f32, one (k, v) tile pair is 2 * CHUNK * 16 * 4 B = 4 KiB at CHUNK=32 —
# deliberately small for TinyLM; for Llama-class head_dim=128 the same
# structure at CHUNK=512 stages 512 KiB, well inside a 16 MiB VMEM budget
# with double buffering.
CHUNK = 32


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, max_seq):
    """One grid step = one KV head.

    Block shapes (leading 1 = the KV-head axis block):
      q_ref: [1, q_rep, head_dim]    k_ref/v_ref: [1, max_seq, head_dim]
      len_ref: [1, 1] int32          o_ref: [1, q_rep, head_dim]
    """
    q = q_ref[0].astype(jnp.float32)          # [q_rep, hd]
    q_rep, head_dim = q.shape
    length = len_ref[0, 0]
    scale = 1.0 / jnp.sqrt(jnp.float32(head_dim))

    num_chunks = max_seq // CHUNK

    def body(c, carry):
        m_prev, l_prev, acc_prev = carry
        start = c * CHUNK
        k_chunk = k_ref[0, pl.dslice(start, CHUNK), :].astype(
            jnp.float32
        )                                      # [CHUNK, hd]
        v_chunk = v_ref[0, pl.dslice(start, CHUNK), :].astype(
            jnp.float32
        )                                      # [CHUNK, hd]

        # MXU-shaped scores for this tile: [q_rep, CHUNK].
        s = (
            jax.lax.dot_general(
                q,
                k_chunk,
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            * scale
        )
        # Mask slots at/after `length`. NB: use a large-negative rather than
        # -inf so fully-masked tiles stay NaN-free in the online update.
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, CHUNK), 1)
        s = jnp.where(pos < length, s, jnp.float32(-1e30))

        # Online softmax update (flash-attention recurrence).
        m_cur = jnp.max(s, axis=-1, keepdims=True)          # [q_rep, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                              # [q_rep, CHUNK]
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc_prev * alpha + jax.lax.dot_general(
            p,
            v_chunk,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((q_rep, 1), -1e30, dtype=jnp.float32)
    l0 = jnp.zeros((q_rep, 1), dtype=jnp.float32)
    acc0 = jnp.zeros((q_rep, head_dim), dtype=jnp.float32)
    _, l_fin, acc_fin = jax.lax.fori_loop(0, num_chunks, body, (m0, l0, acc0))

    o_ref[0] = acc_fin / l_fin


def gqa_decode_attention(q, k_cache, v_cache, length):
    """Pallas GQA decode attention; drop-in for `ref.gqa_decode_attention_ref`.

    Args:
      q:        [num_heads, head_dim]
      k_cache:  [max_seq, kv_heads, head_dim]
      v_cache:  [max_seq, kv_heads, head_dim]
      length:   scalar int32 — valid cache length.

    Returns:
      [num_heads, head_dim] float32.
    """
    num_heads, head_dim = q.shape
    max_seq, kv_heads, _ = k_cache.shape
    q_rep = num_heads // kv_heads
    assert max_seq % CHUNK == 0, f"max_seq {max_seq} must be a multiple of {CHUNK}"

    # Group query heads by their KV head: head h -> kv head h // q_rep.
    qg = q.reshape(kv_heads, q_rep, head_dim)
    kg = jnp.swapaxes(k_cache, 0, 1)           # [kv_heads, max_seq, hd]
    vg = jnp.swapaxes(v_cache, 0, 1)
    len_arr = jnp.asarray(length, dtype=jnp.int32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_decode_attn_kernel, max_seq=max_seq),
        grid=(kv_heads,),
        in_specs=[
            pl.BlockSpec((1, q_rep, head_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, max_seq, head_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, max_seq, head_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_rep, head_dim), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (kv_heads, q_rep, head_dim), jnp.float32
        ),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(qg, kg, vg, len_arr)

    return out.reshape(num_heads, head_dim)
