"""L1 Pallas kernels + pure-jnp oracles (build-time only)."""

from .attention import gqa_decode_attention  # noqa: F401
from .ref import (  # noqa: F401
    causal_prefill_attention_ref,
    gqa_decode_attention_ref,
)
