"""Pure-jnp correctness oracle for the Pallas decode-attention kernel.

This is the CORE correctness signal: `python/tests/test_kernel.py` sweeps
shapes/dtypes (hypothesis) and asserts the Pallas kernel matches this oracle.
No Pallas, no tricks — a direct transcription of masked GQA attention.
"""

import jax.numpy as jnp


def gqa_decode_attention_ref(q, k_cache, v_cache, length):
    """Masked grouped-query decode attention, reference implementation.

    Args:
      q:        [num_heads, head_dim] — query for the single decode token.
      k_cache:  [max_seq, kv_heads, head_dim] — padded key cache.
      v_cache:  [max_seq, kv_heads, head_dim] — padded value cache.
      length:   scalar int — number of valid cache slots (mask the rest).

    Returns:
      [num_heads, head_dim] attention output, float32.
    """
    num_heads, head_dim = q.shape
    max_seq, kv_heads, _ = k_cache.shape
    q_rep = num_heads // kv_heads

    qf = q.astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)

    # [num_heads, max_seq]: score of every head against every cache slot.
    # Head h attends to KV head h // q_rep.
    kv_index = jnp.arange(num_heads) // q_rep
    k_per_head = kf[:, kv_index, :]            # [max_seq, num_heads, head_dim]
    scores = jnp.einsum("hd,shd->hs", qf, k_per_head) / jnp.sqrt(
        jnp.float32(head_dim)
    )

    mask = jnp.arange(max_seq)[None, :] < length    # [1, max_seq]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)

    v_per_head = vf[:, kv_index, :]            # [max_seq, num_heads, head_dim]
    return jnp.einsum("hs,shd->hd", probs, v_per_head)


def causal_prefill_attention_ref(q, k, v, q_rep):
    """Causal GQA attention over a full prompt (prefill), reference.

    Args:
      q: [T, num_heads, head_dim]
      k: [T, kv_heads, head_dim]
      v: [T, kv_heads, head_dim]
      q_rep: query heads per KV head.

    Returns:
      [T, num_heads, head_dim] float32.
    """
    t, num_heads, head_dim = q.shape
    kv_index = jnp.arange(num_heads) // q_rep
    kf = k.astype(jnp.float32)[:, kv_index, :]  # [T, num_heads, head_dim]
    vf = v.astype(jnp.float32)[:, kv_index, :]
    scores = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32), kf)
    scores = scores / jnp.sqrt(jnp.float32(head_dim))
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(causal[None, :, :], scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", probs, vf)
