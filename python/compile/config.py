"""TinyLM configuration — the small GQA transformer served end-to-end.

The paper evaluates Llama2-13B / Qwen3-32B / Llama3.3-70B on physical Jetson
boards; those shapes live in the Rust simulator (`model::spec`). This config
defines the *real* model that flows through the PJRT request path: a
synthetic-weight GQA decoder small enough to AOT-compile and serve on the CPU
PJRT client while exercising every code path LIME needs (per-layer artifacts,
MHA/MLP split blocks for fine-grained offload, explicit KV caches owned by the
Rust coordinator).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class TinyLMConfig:
    vocab: int = 256
    hidden: int = 128
    layers: int = 8
    heads: int = 8          # query heads
    kv_heads: int = 2       # GQA: 4 query heads share one KV head
    ffn: int = 384          # SwiGLU inner width
    prefill_len: int = 16   # fixed-length prompt (paper follows EdgeShard's
                            # fixed input/output paradigm)
    max_seq: int = 128      # KV cache capacity (padded, mask-gated)
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    @property
    def q_rep(self) -> int:
        """Query heads per KV head (GQA replication factor)."""
        assert self.heads % self.kv_heads == 0
        return self.heads // self.kv_heads


CFG = TinyLMConfig()
